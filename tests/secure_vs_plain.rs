//! Losslessness, end to end: the secure FSL training loop must produce a
//! model *bit-identical* to the plaintext FedAvg loop with the same
//! seeds — the paper's headline "lossless" claim (vs Niu et al.'s
//! DP-noised aggregation), demonstrated at the system level.

use fsl::coordinator::{run_fsl_training, run_plain_training, FslConfig};
use fsl::crypto::rng::Rng;
use fsl::data::{partition_iid, ImageDataset};
use fsl::runtime::Executor;

#[test]
fn secure_training_equals_plain_training() {
    let exec = Executor::new("artifacts").expect("artifact manifest unreadable");
    let m = exec.manifest().int("mlp_grad", "params").unwrap() as usize;
    let batch = exec.manifest().int("mlp_grad", "batch").unwrap() as usize;

    let cfg = FslConfig {
        num_clients: 3,
        participation: 1.0,
        rounds: 2,
        local_iters: 1,
        lr: 0.05,
        compression: 0.02,
        seed: 999,
        eval_every: 0,
        ..FslConfig::default()
    };
    let train = ImageDataset::synthesize(300, 1, 1.0);
    let mut rng = Rng::new(cfg.seed);
    let shards = partition_iid(train.n, cfg.num_clients, &mut rng);

    let mut prng = Rng::new(5);
    let params: Vec<f32> = (0..m).map(|_| prng.gen_normal() as f32 * 0.02).collect();

    let batch_fn = |shards: &Vec<Vec<usize>>, train: &ImageDataset| {
        let shards = shards.clone();
        let train = train.clone();
        move |client: usize, _it: usize, r: &mut Rng| {
            let shard = &shards[client];
            let idx: Vec<usize> = (0..batch)
                .map(|_| shard[r.gen_range(shard.len() as u64) as usize])
                .collect();
            train.batch(&idx)
        }
    };

    let secure = run_fsl_training(
        &exec,
        &cfg,
        "mlp_grad",
        params.clone(),
        batch_fn(&shards, &train),
        |_p| Ok(0.0),
        |_s| {},
    )
    .unwrap();
    let plain = run_plain_training(&exec, &cfg, "mlp_grad", params, batch_fn(&shards, &train))
        .unwrap();

    assert_eq!(secure.final_params.len(), plain.len());
    let diffs = secure
        .final_params
        .iter()
        .zip(&plain)
        .filter(|(a, b)| a.to_bits() != b.to_bits())
        .count();
    assert_eq!(
        diffs, 0,
        "secure and plain models diverge in {diffs} parameters — aggregation is not lossless"
    );
}
