//! The persistent-runtime contract: one `FslRuntime` serves many rounds
//! of different types against the same living server threads, with
//! per-round metering that resets, results bit-identical to the one-shot
//! deprecated wrappers, and a clean shutdown (no hung threads).

use fsl::coordinator::{FslRuntimeBuilder, KeyMode, RoundKind};
use fsl::crypto::field::Fp;
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::protocol::{ssa, Session, SessionParams};
use std::time::Duration;

fn session(m: u64, k: usize) -> Session {
    Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default(),
    })
}

/// PSR, then SSA, then a second SSA round through one runtime: every
/// round's payload is bit-identical to the deprecated one-shot wrapper
/// run from the same rng seed, the per-round reports reset instead of
/// accumulating, and shutdown joins both server threads.
#[test]
#[allow(deprecated)] // equivalence vs the one-shot wrappers is the point
fn one_runtime_serves_psr_then_ssa_then_ssa_bit_identically() {
    let s = session(2048, 32);
    let weights: Vec<u64> = {
        let mut rng = Rng::new(40);
        (0..2048).map(|_| rng.next_u64()).collect()
    };
    let selections: Vec<Vec<u64>> = {
        let mut rng = Rng::new(41);
        (0..3).map(|_| rng.sample_distinct(32, 2048)).collect()
    };
    let clients_of = |seed: u64| -> Vec<(Vec<u64>, Vec<u64>)> {
        let mut rng = Rng::new(seed);
        selections
            .iter()
            .map(|sel| (sel.clone(), sel.iter().map(|&x| x ^ rng.next_u64()).collect()))
            .collect()
    };
    let round_b = clients_of(42);
    let round_c = clients_of(43);

    let mut rt = FslRuntimeBuilder::from_session(s.clone())
        .threads(2)
        .max_clients(3)
        .build::<u64>()
        .unwrap();
    rt.set_weights(weights.clone()).unwrap();

    // Round A: PSR.
    let psr = rt.psr(&selections, &mut Rng::new(11)).unwrap();
    let legacy_psr = fsl::coordinator::run_psr_round(
        &s,
        &weights,
        &selections,
        &mut Rng::new(11),
        Duration::ZERO,
    )
    .unwrap();
    assert_eq!(psr.submodels, legacy_psr.submodels, "PSR bit-identity");
    assert_eq!(psr.report.kind, RoundKind::Psr);
    assert_eq!(psr.report.client_upload_bytes, legacy_psr.client_upload_bytes);
    assert_eq!(psr.report.client_download_bytes, legacy_psr.client_download_bytes);
    assert!(psr.report.client_download_bytes > 0);

    // Round B: SSA through the *same* runtime.
    let ssa_b = rt.ssa(&round_b, &mut Rng::new(12)).unwrap();
    let legacy_b =
        fsl::coordinator::run_ssa_round(&s, &round_b, &mut Rng::new(12), Duration::ZERO).unwrap();
    assert_eq!(ssa_b.delta, legacy_b.delta, "SSA round B bit-identity");
    assert_eq!(ssa_b.report.kind, RoundKind::Ssa);
    assert_eq!(ssa_b.report.client_upload_bytes, legacy_b.client_upload_bytes);

    // Round C: a second SSA round; the report must cover only this round.
    let ssa_c = rt.ssa(&round_c, &mut Rng::new(13)).unwrap();
    let legacy_c =
        fsl::coordinator::run_ssa_round(&s, &round_c, &mut Rng::new(13), Duration::ZERO).unwrap();
    assert_eq!(ssa_c.delta, legacy_c.delta, "SSA round C bit-identity");
    // Metering resets between rounds: round C's counters equal a fresh
    // one-shot run (message shapes are data-independent, so equal sizes),
    // not the running sum of rounds A + B + C.
    assert_eq!(ssa_c.report.client_upload_bytes, legacy_c.client_upload_bytes);
    assert_eq!(ssa_c.report.client_upload_bytes, ssa_b.report.client_upload_bytes);
    assert_eq!(ssa_c.report.client_download_bytes, 0, "SSA downloads nothing");
    assert_eq!(ssa_c.report.server_exchange_bytes, legacy_c.server_exchange_bytes);

    // Clean shutdown: both server threads join (a hang fails the test
    // harness; a panicked server surfaces as Err here).
    rt.shutdown().unwrap();
}

/// Verified SSA and PSU alignment are reachable through the same builder
/// API, bit-identical to their deprecated one-shot wrappers, and the
/// union session installed by `psu_align` keeps serving SSA rounds.
#[test]
#[allow(deprecated)] // equivalence vs the one-shot wrappers is the point
fn verified_and_psu_rounds_match_the_one_shot_wrappers() {
    // --- Verified SSA (Fp payloads, one malformed client) ----------------
    let s = session(512, 16);
    let mut rng = Rng::new(50);
    let mut uploads = Vec::new();
    for _ in 0..2 {
        let sel = rng.sample_distinct(16, 512);
        let dl: Vec<Fp> = sel.iter().map(|&x| Fp::new(x + 1)).collect();
        uploads.push(ssa::client_update(&s, &sel, &dl, &mut rng).unwrap());
    }
    let mut evil = uploads[1].clone();
    evil.publics.pop(); // wrong key count ⇒ must be rejected
    uploads.push(evil);

    let mut rt = FslRuntimeBuilder::from_session(s.clone())
        .max_clients(3)
        .build::<Fp>()
        .unwrap();
    let got = rt.verified_ssa(uploads.clone(), 51).unwrap();
    let legacy = fsl::coordinator::run_verified_ssa_round(&s, &uploads, 51).unwrap();
    assert_eq!(got.delta, legacy.delta, "verified delta bit-identity");
    assert_eq!(got.rejected, legacy.rejected);
    assert_eq!(got.rejected, vec![2]);
    assert_eq!(got.report.kind, RoundKind::VerifiedSsa);
    rt.shutdown().unwrap();

    // --- PSU alignment ---------------------------------------------------
    let m = 4096u64;
    let k = 16usize;
    let params = SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default(),
    };
    let sets: Vec<Vec<u64>> = {
        let mut rng = Rng::new(52);
        (0..4)
            .map(|_| {
                let mut v = rng.sample_distinct(12, 256); // clustered region
                v.sort_unstable();
                v
            })
            .collect()
    };
    let key = [7u8; 16];
    let mut rt = FslRuntimeBuilder::new(params.clone())
        .max_clients(4)
        .build::<u64>()
        .unwrap();
    let psu = rt.psu_align(&key, &sets, &mut Rng::new(53)).unwrap();
    let legacy_session =
        fsl::protocol::psu::run_psu_session(&key, params, &sets, &mut Rng::new(53)).unwrap();
    assert_eq!(psu.report.kind, RoundKind::PsuAlign);
    assert_eq!(
        rt.session().domain.as_deref(),
        legacy_session.domain.as_deref(),
        "union domain bit-identity"
    );
    assert_eq!(rt.session().theta(), legacy_session.theta());
    assert_eq!(psu.union_len, rt.session().domain_size());

    // The installed union session keeps serving rounds.
    let clients: Vec<(Vec<u64>, Vec<u64>)> = sets
        .iter()
        .map(|s| (s.clone(), s.iter().map(|&x| x + 5).collect()))
        .collect();
    let out = rt.ssa(&clients, &mut Rng::new(54)).unwrap();
    for (pos, delta) in out.delta.iter().enumerate() {
        let idx = rt.session().domain_value(pos);
        let expect: u64 = clients
            .iter()
            .flat_map(|(sel, dl)| {
                sel.iter().zip(dl).filter(|(s, _)| **s == idx).map(|(_, d)| *d)
            })
            .fold(0u64, |a, b| a.wrapping_add(b));
        assert_eq!(*delta, expect, "union position {pos}");
    }
    rt.shutdown().unwrap();
}

/// U-DPF key mode: the first round ships full retained key sets, later
/// rounds ship only hints — far smaller on the wire — and every epoch
/// reconstructs exactly. Changing the client set mid-task is an error.
#[test]
fn udpf_key_mode_amortises_uploads_and_stays_lossless() {
    let s = session(512, 16);
    let selections: Vec<Vec<u64>> = {
        let mut rng = Rng::new(60);
        (0..2).map(|_| rng.sample_distinct(16, 512)).collect()
    };
    let deltas_at = |epoch: u64| -> Vec<(Vec<u64>, Vec<u64>)> {
        selections
            .iter()
            .map(|sel| (sel.clone(), sel.iter().map(|&x| x * 3 + epoch + 1).collect()))
            .collect()
    };
    let mut rt = FslRuntimeBuilder::from_session(s.clone())
        .key_mode(KeyMode::Udpf)
        .max_clients(2)
        .build::<u64>()
        .unwrap();
    let mut rng = Rng::new(61);
    let mut setup_bytes = 0;
    for epoch in 0..3u64 {
        let clients = deltas_at(epoch);
        let out = rt.ssa(&clients, &mut rng).unwrap();
        let mut expected = vec![0u64; 512];
        for (sel, dl) in &clients {
            for (&i, &d) in sel.iter().zip(dl) {
                expected[i as usize] = expected[i as usize].wrapping_add(d);
            }
        }
        assert_eq!(out.delta, expected, "epoch {epoch} lossless");
        if epoch == 0 {
            setup_bytes = out.report.client_upload_bytes;
        } else {
            assert!(
                out.report.client_upload_bytes * 4 < setup_bytes,
                "epoch {epoch}: hint upload {} should be ≪ setup upload {setup_bytes}",
                out.report.client_upload_bytes
            );
        }
    }
    // The fixed-submodel contract: the client set cannot change.
    let err = rt
        .ssa(&deltas_at(9)[..1], &mut rng)
        .unwrap_err()
        .to_string();
    assert!(err.contains("fixed"), "{err}");
    rt.shutdown().unwrap();
}

/// `from_config` validates before any thread is spawned.
#[test]
fn builder_from_config_rejects_invalid_configs() {
    use fsl::coordinator::FslConfig;
    let err = FslRuntimeBuilder::from_config(
        &FslConfig {
            compression: 0.0,
            ..FslConfig::default()
        },
        1024,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("compression"), "{err}");
    let err = FslRuntimeBuilder::from_config(
        &FslConfig {
            participation: -1.0,
            ..FslConfig::default()
        },
        1024,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("participation"), "{err}");
    let cfg = FslConfig::default();
    let rt = FslRuntimeBuilder::from_config(&cfg, 1024)
        .unwrap()
        .build::<u64>()
        .unwrap();
    assert_eq!(rt.session().params.k, 102); // 1024 · 0.1, rounded
    assert_eq!(rt.max_clients(), cfg.participants());
    rt.shutdown().unwrap();
}
