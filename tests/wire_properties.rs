//! Property-style fuzzing of the wire layer (`protocol/msg.rs`).
//!
//! No proptest crate is available offline, so these are seed-swept
//! properties plus exhaustive adversarial sweeps: every wire message must
//! (a) encode→decode round-trip bit-exactly, (b) decode to `None` from
//! every strict prefix (truncation must never yield a plausible partial
//! message), and (c) never panic or over-read on corrupted or random
//! bytes — decoders only ever see attacker-controlled channel data.

use fsl::crypto::rng::Rng;
use fsl::crypto::Sensitive;
use fsl::dpf::{gen_batch_with_master, BinPoint, MasterKeyBatch};
use fsl::group::{Group, MegaElem};
use fsl::protocol::msg;

/// A random key batch with mixed real/dummy bins and mixed depths.
fn random_batch<G: Group>(
    rng: &mut Rng,
    bins: usize,
    beta: impl Fn(&mut Rng) -> G,
) -> MasterKeyBatch<G> {
    let points: Vec<BinPoint<G>> = (0..bins)
        .map(|_| {
            let depth = 1 + rng.gen_range(9) as usize;
            let point = if rng.gen_f64() < 0.25 {
                None // dummy bin
            } else {
                Some((rng.gen_range(1u64 << depth), beta(rng)))
            };
            BinPoint { depth, point }
        })
        .collect();
    gen_batch_with_master(&points, rng.gen_seed(), rng.gen_seed())
}

#[test]
fn prop_key_upload_roundtrips() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed);
        let bins = 1 + rng.gen_range(12) as usize;
        let batch = random_batch::<u64>(&mut rng, bins, |r| r.next_u64());
        for server in 0..2u8 {
            let long = msg::encode_key_upload(&batch, server, true);
            let up = msg::decode_key_upload::<u64>(&long).expect("long upload decodes");
            assert_eq!(up.server, server, "seed {seed}");
            assert_eq!(up.msk, *batch.msk[server as usize], "seed {seed}");
            // Re-encoding the decoded upload must reproduce the publics
            // region byte-exactly (deep equality of every correction
            // word); bytes 0..17 are the server tag + per-server msk.
            let rebuilt = MasterKeyBatch::<u64> {
                msk: [Sensitive::new(up.msk), Sensitive::new(up.msk)],
                publics: up.publics.expect("publics present"),
            };
            assert_eq!(
                msg::encode_key_upload(&rebuilt, 0, true)[17..],
                msg::encode_key_upload(&batch, 0, true)[17..],
                "seed {seed} server {server}"
            );
            let short = msg::encode_key_upload(&batch, server, false);
            assert!(short.len() < long.len(), "seed {seed}");
            let us = msg::decode_key_upload::<u64>(&short).expect("short upload decodes");
            assert!(us.publics.is_none(), "seed {seed}");
            assert_eq!(us.msk, *batch.msk[server as usize], "seed {seed}");
        }
    }
}

#[test]
fn prop_shares_and_indices_roundtrip() {
    for seed in 100..140u64 {
        let mut rng = Rng::new(seed);
        let n = rng.gen_range(200) as usize;
        let shares64: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        assert_eq!(
            msg::decode_shares::<u64>(&msg::encode_shares(&shares64)).as_deref(),
            Some(&shares64[..]),
            "seed {seed} u64"
        );
        let shares128: Vec<u128> = (0..n)
            .map(|_| ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128)
            .collect();
        assert_eq!(
            msg::decode_shares::<u128>(&msg::encode_shares(&shares128)).as_deref(),
            Some(&shares128[..]),
            "seed {seed} u128"
        );
        let mega: Vec<MegaElem<3>> = (0..n)
            .map(|_| MegaElem([rng.next_u64(), rng.next_u64(), rng.next_u64()]))
            .collect();
        assert_eq!(
            msg::decode_shares::<MegaElem<3>>(&msg::encode_shares(&mega)).as_deref(),
            Some(&mega[..]),
            "seed {seed} mega"
        );
        let idx: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        assert_eq!(
            msg::decode_indices(&msg::encode_indices(&idx)).as_deref(),
            Some(&idx[..]),
            "seed {seed} indices"
        );
    }
}

#[test]
fn prop_every_strict_prefix_is_rejected() {
    for seed in 200..210u64 {
        let mut rng = Rng::new(seed);
        let batch = random_batch::<u128>(&mut rng, 1 + rng.gen_range(6) as usize, |r| {
            r.next_u64() as u128
        });
        let n_shares = 1 + rng.gen_range(40) as usize;
        let shares: Vec<u64> = (0..n_shares).map(|_| rng.next_u64()).collect();
        let n_idx = 1 + rng.gen_range(40) as usize;
        let idx: Vec<u64> = (0..n_idx).map(|_| rng.next_u64()).collect();
        // Each message against its own decoder: a truncated message must
        // decode to None at EVERY cut point — partial parses must never
        // yield a plausible message.
        for (mi, bytes) in [
            msg::encode_key_upload(&batch, 0, true),
            msg::encode_key_upload(&batch, 1, false),
        ]
        .iter()
        .enumerate()
        {
            for len in 0..bytes.len() {
                assert!(
                    msg::decode_key_upload::<u128>(&bytes[..len]).is_none(),
                    "seed {seed} upload {mi} len {len}"
                );
            }
        }
        let enc_shares = msg::encode_shares(&shares);
        for len in 0..enc_shares.len() {
            assert!(
                msg::decode_shares::<u64>(&enc_shares[..len]).is_none(),
                "seed {seed} shares len {len}"
            );
        }
        let enc_idx = msg::encode_indices(&idx);
        for len in 0..enc_idx.len() {
            assert!(
                msg::decode_indices(&enc_idx[..len]).is_none(),
                "seed {seed} indices len {len}"
            );
        }
    }
}

#[test]
fn prop_corrupted_bytes_never_panic() {
    // Single-byte corruption at every position, two flip patterns: the
    // decoder may return garbage-but-well-formed data, but it must never
    // panic, loop, or read out of bounds (all access is bounds-checked —
    // this test pins that contract).
    for seed in 300..306u64 {
        let mut rng = Rng::new(seed);
        let batch = random_batch::<u64>(&mut rng, 1 + rng.gen_range(5) as usize, |r| r.next_u64());
        let shares: Vec<u64> = (0..10).map(|_| rng.next_u64()).collect();
        let messages: Vec<Vec<u8>> = vec![
            msg::encode_key_upload(&batch, 0, true),
            msg::encode_key_upload(&batch, 1, false),
            msg::encode_shares(&shares),
            msg::encode_indices(&shares),
        ];
        for bytes in &messages {
            for pos in 0..bytes.len() {
                for flip in [0x01u8, 0xff] {
                    let mut bad = bytes.clone();
                    bad[pos] ^= flip;
                    // Outputs are unspecified; absence of panic is the
                    // property. Where Some comes back, the decoded value
                    // must at least re-encode within the input's length
                    // (no over-read can have happened).
                    if let Some(v) = msg::decode_shares::<u64>(&bad) {
                        assert!(4 + v.len() * 8 <= bad.len(), "over-read at {pos}");
                    }
                    if let Some(v) = msg::decode_indices(&bad) {
                        assert!(4 + v.len() * 8 <= bad.len(), "over-read at {pos}");
                    }
                    let _ = msg::decode_key_upload::<u64>(&bad);
                }
            }
        }
    }
}

#[test]
fn prop_random_blobs_never_panic() {
    // Pure-noise inputs of sweeping lengths against every decoder.
    for seed in 400..420u64 {
        let mut rng = Rng::new(seed);
        let len = rng.gen_range(600) as usize;
        let blob: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = msg::decode_key_upload::<u64>(&blob);
        let _ = msg::decode_key_upload::<u128>(&blob);
        let _ = msg::decode_key_upload::<MegaElem<4>>(&blob);
        if let Some(v) = msg::decode_shares::<u64>(&blob) {
            assert!(4 + v.len() * 8 <= blob.len(), "seed {seed} over-read");
        }
        if let Some(v) = msg::decode_indices(&blob) {
            assert!(4 + v.len() * 8 <= blob.len(), "seed {seed} over-read");
        }
    }
}

#[test]
fn prop_frame_roundtrips_and_rejects_every_corruption() {
    // The frame envelope (magic + version + length) in front of every
    // stream-transport message: round-trips bit-exactly, rejects every
    // strict prefix as Truncated, and classifies every single-byte header
    // corruption as a *typed* error (never a panic, never a silent
    // misparse into a different payload).
    for seed in 500..520u64 {
        let mut rng = Rng::new(seed);
        let len = rng.gen_range(300) as usize;
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let framed = msg::frame(&payload);
        assert_eq!(framed.len(), msg::FRAME_HEADER_LEN + payload.len());
        assert_eq!(msg::unframe(&framed).unwrap(), &payload[..], "seed {seed}");
        assert_eq!(
            msg::frame_payload_len(&framed).unwrap(),
            payload.len(),
            "seed {seed}"
        );

        // Every strict prefix is a truncation.
        for cut in 0..framed.len() {
            assert!(
                matches!(
                    msg::unframe(&framed[..cut]),
                    Err(msg::FrameError::Truncated { .. })
                ),
                "seed {seed} cut {cut}"
            );
        }

        // Single-byte corruption at every header position, two flip
        // patterns: the error is typed by which field broke.
        for pos in 0..msg::FRAME_HEADER_LEN {
            for flip in [0x01u8, 0xff] {
                let mut bad = framed.clone();
                bad[pos] ^= flip;
                let res = msg::unframe(&bad);
                match pos {
                    0 | 1 => assert!(
                        matches!(res, Err(msg::FrameError::BadMagic(_))),
                        "seed {seed} pos {pos}"
                    ),
                    2 => assert!(
                        matches!(res, Err(msg::FrameError::BadVersion(_))),
                        "seed {seed} pos {pos}"
                    ),
                    // A corrupted length field must surface as Oversize
                    // or Truncated — and never accept the frame, since
                    // the length can only change away from the truth.
                    _ => assert!(
                        matches!(
                            res,
                            Err(msg::FrameError::Oversize(_))
                                | Err(msg::FrameError::Truncated { .. })
                        ),
                        "seed {seed} pos {pos} flip {flip:#x}: {res:?}"
                    ),
                }
            }
        }

        // Corrupting the payload leaves the envelope valid (payload
        // integrity is the inner decoder's problem, by design).
        if !payload.is_empty() {
            let mut bad = framed.clone();
            let pos = msg::FRAME_HEADER_LEN + rng.gen_range(payload.len() as u64) as usize;
            bad[pos] ^= 0xff;
            assert!(msg::unframe(&bad).is_ok(), "seed {seed}");
        }
    }
}

#[test]
fn frame_oversize_guard_fires_before_allocation() {
    // A length field claiming more than MAX_FRAME_LEN is rejected from
    // the 7 header bytes alone — no payload allocation can happen.
    let mut header = Vec::new();
    header.extend_from_slice(&msg::FRAME_MAGIC);
    header.push(msg::FRAME_VERSION);
    header.extend_from_slice(&u32::MAX.to_le_bytes());
    assert_eq!(
        msg::frame_payload_len(&header),
        Err(msg::FrameError::Oversize(u32::MAX as usize))
    );
    // The error Display names the limit (actionable without the source).
    let rendered = msg::FrameError::Oversize(u32::MAX as usize).to_string();
    assert!(rendered.contains(&msg::MAX_FRAME_LEN.to_string()), "{rendered}");
}

#[test]
fn adversarial_length_fields_are_bounded_before_allocation() {
    // A malicious count must be rejected by the pre-allocation bound, not
    // by OOM: huge counts over tiny payloads return None.
    let mut huge_shares = Vec::new();
    huge_shares.extend_from_slice(&u32::MAX.to_le_bytes());
    huge_shares.extend_from_slice(&[0u8; 64]);
    assert!(msg::decode_shares::<u64>(&huge_shares).is_none());
    assert!(msg::decode_indices(&huge_shares).is_none());

    // Same for the publics count inside a key upload.
    let mut upload = vec![0u8]; // server
    upload.extend_from_slice(&[7u8; 16]); // msk
    upload.push(1); // has_publics
    upload.extend_from_slice(&u32::MAX.to_le_bytes());
    upload.extend_from_slice(&[0u8; 32]);
    assert!(msg::decode_key_upload::<u64>(&upload).is_none());
}
