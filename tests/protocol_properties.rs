//! Property-style randomized sweeps over the protocol invariants.
//!
//! No proptest crate is available offline, so these are seed-swept
//! properties: each test draws many random configurations (η, ε, σ, m, k,
//! payload group) and asserts the protocol invariants hold for all of
//! them. Failures print the offending seed for reproduction.

use fsl::crypto::rng::Rng;
use fsl::group::{Group, MegaElem};
use fsl::hashing::{CuckooParams, CuckooTable};
use fsl::protocol::{mega, psr, psu, ssa, RetrievalEngine, Session, SessionParams};

fn random_params(rng: &mut Rng) -> CuckooParams {
    CuckooParams {
        epsilon: 1.2 + rng.gen_f64() * 0.4,
        eta: 2 + rng.gen_range(3) as usize, // 2..=4
        sigma: if rng.gen_f64() < 0.3 { 4 } else { 0 },
        hash_seed: rng.next_u64(),
        max_kicks: 500,
    }
}

#[test]
fn prop_psr_always_correct() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let m = 256 + rng.gen_range(4096);
        let k = (1 + rng.gen_range(64)) as usize;
        let k = k.min(m as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: random_params(&mut rng),
        });
        let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
        let sel = rng.sample_distinct(k, m);
        let Ok((ctx, batch)) = psr::client_query::<u64>(&session, &sel, &mut rng) else {
            continue; // rare cuckoo failure with tight random ε — skip
        };
        let engine = RetrievalEngine::serial();
        let a0 = engine.answer_keys(&session, &weights, &batch.server_keys(0));
        let a1 = engine.answer_keys(&session, &weights, &batch.server_keys(1));
        let got = psr::client_reconstruct(&ctx, session.simple.num_bins(), &sel, &a0, &a1);
        for (i, &s) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[s as usize], "seed {seed} sel {s}");
        }
    }
}

#[test]
fn prop_ssa_sums_match_plaintext() {
    for seed in 100..130u64 {
        let mut rng = Rng::new(seed);
        let m = 128 + rng.gen_range(2048);
        let k = ((1 + rng.gen_range(32)) as usize).min(m as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: random_params(&mut rng),
        });
        let n = 1 + rng.gen_range(5) as usize;
        let mut expected = vec![0u64; m as usize];
        let mut keys0 = Vec::new();
        let mut keys1 = Vec::new();
        let mut ok = true;
        for _ in 0..n {
            let sel = rng.sample_distinct(k, m);
            let dl: Vec<u64> = sel.iter().map(|_| rng.next_u64()).collect();
            match ssa::client_update(&session, &sel, &dl, &mut rng) {
                Ok(batch) => {
                    for (&i, &d) in sel.iter().zip(&dl) {
                        expected[i as usize] = expected[i as usize].wrapping_add(d);
                    }
                    keys0.push(batch.server_keys(0));
                    keys1.push(batch.server_keys(1));
                }
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        let dw = ssa::reconstruct(
            &ssa::server_aggregate(&session, &keys0),
            &ssa::server_aggregate(&session, &keys1),
        );
        assert_eq!(dw, expected, "seed {seed}");
    }
}

#[test]
fn prop_ssa_mega_elements() {
    for seed in 200..215u64 {
        let mut rng = Rng::new(seed);
        let rows = 64 + rng.gen_range(512);
        let k = ((1 + rng.gen_range(16)) as usize).min(rows as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m: rows,
            k,
            cuckoo: CuckooParams::default().with_seed(seed),
        });
        let sel = rng.sample_distinct(k, rows);
        let dl: Vec<MegaElem<6>> = sel
            .iter()
            .map(|_| {
                let mut e = [0u64; 6];
                for v in &mut e {
                    *v = rng.next_u64();
                }
                MegaElem(e)
            })
            .collect();
        let batch = ssa::client_update(&session, &sel, &dl, &mut rng).unwrap();
        let dw = ssa::reconstruct(
            &ssa::server_aggregate(&session, &[batch.server_keys(0)]),
            &ssa::server_aggregate(&session, &[batch.server_keys(1)]),
        );
        for (pos, val) in dw.iter().enumerate() {
            match sel.iter().position(|&s| s == pos as u64) {
                Some(i) => assert_eq!(*val, dl[i], "seed {seed}"),
                None => assert_eq!(*val, MegaElem::zero(), "seed {seed}"),
            }
        }
    }
}

#[test]
fn prop_mega_group_roundtrip() {
    for seed in 300..340u64 {
        let mut rng = Rng::new(seed);
        let m = 1 + rng.gen_range(500) as usize;
        let w: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
        let g = mega::group_weights::<7>(&w);
        assert_eq!(mega::ungroup_weights(&g, m), w, "seed {seed} m {m}");
    }
}

#[test]
fn prop_psu_equals_set_union() {
    for seed in 400..420u64 {
        let mut rng = Rng::new(seed);
        let m = 512 + rng.gen_range(8192);
        let k = (4 + rng.gen_range(32)) as usize;
        let n = 2 + rng.gen_range(6) as usize;
        let key = rng.gen_seed();
        let sets: Vec<Vec<u64>> = (0..n)
            .map(|_| {
                let take = 1 + rng.gen_range(k as u64 - 1) as usize;
                rng.sample_distinct(take, m)
            })
            .collect();
        let mut expected: Vec<u64> = sets.iter().flatten().copied().collect();
        expected.sort_unstable();
        expected.dedup();
        assert_eq!(psu::run_psu(&key, m, k, &sets, &mut rng), expected, "seed {seed}");
    }
}

#[test]
fn prop_cuckoo_locate_total() {
    // Every inserted element is locatable; every absent element is not.
    for seed in 500..540u64 {
        let mut rng = Rng::new(seed);
        let k = 1 + rng.gen_range(300) as usize;
        let m = (k as u64) * 8;
        let params = random_params(&mut rng);
        let elements = rng.sample_distinct(k, m);
        let Ok(table) = CuckooTable::build(&elements, &params, &mut rng) else {
            continue;
        };
        for &e in &elements {
            assert!(table.locate(e).is_some(), "seed {seed} lost {e}");
        }
        for probe in 0..20 {
            let x = m + probe; // guaranteed absent
            assert!(table.locate(x).is_none(), "seed {seed} ghost {x}");
        }
    }
}

#[test]
fn prop_dpf_key_sizes_follow_formula() {
    use fsl::dpf::{gen, DpfKey};
    for seed in 600..640u64 {
        let mut rng = Rng::new(seed);
        let depth = 1 + rng.gen_range(16) as usize;
        let alpha = rng.gen_range(1 << depth);
        let (k0, _k1) = gen::<u128>(depth, alpha, &7u128, rng.gen_seed(), rng.gen_seed());
        assert_eq!(k0.size_bits(), depth * 130 + 128 + 128, "seed {seed}");
        let bytes = k0.to_bytes();
        let parsed = DpfKey::<u128>::from_bytes(&bytes).unwrap();
        assert_eq!(parsed.to_bytes(), bytes);
    }
}

#[test]
#[allow(deprecated)]
fn prop_retrieval_engine_matches_legacy_over_forms_and_widths() {
    // The read-path mirror of `prop_engine_forms_and_widths_agree`: the
    // sharded retrieval engine must produce bit-identical PSR answers to
    // the legacy serial loop across worker counts {1, 2, 3, 8, 64} and
    // across its DPF input forms (materialised keys vs zero-copy publics
    // + master seed), including sessions with an occupied stash (σ > 0).
    use fsl::protocol::aggregate::uploads_of;
    for seed in 1000..1012u64 {
        let mut rng = Rng::new(seed);
        let m = 128 + rng.gen_range(2048);
        let k = ((1 + rng.gen_range(32)) as usize).min(m as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: random_params(&mut rng),
        });
        let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
        let n = 1 + rng.gen_range(4) as usize;
        let mut batches = Vec::new();
        let mut ok = true;
        for _ in 0..n {
            let sel = rng.sample_distinct(k, m);
            match psr::client_query::<u64>(&session, &sel, &mut rng) {
                Ok((_ctx, b)) => batches.push(b),
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue; // rare cuckoo failure with tight random ε — skip
        }
        for party in 0..2u8 {
            let keys: Vec<_> = batches.iter().map(|b| b.server_keys(party)).collect();
            let legacy: Vec<Vec<u64>> = keys
                .iter()
                .map(|k| psr::server_answer(&session, &weights, k))
                .collect();
            for threads in [1usize, 2, 3, 8, 64] {
                assert_eq!(
                    RetrievalEngine::new(threads).answer_batch_keys(&session, &weights, &keys),
                    legacy,
                    "seed {seed} party {party} threads {threads}"
                );
            }
            let uploads = uploads_of(&batches, party);
            assert_eq!(
                RetrievalEngine::new(4).answer_publics(&session, &weights, party, &uploads),
                legacy,
                "seed {seed} party {party} publics form"
            );
        }
    }
}

#[test]
fn prop_cuckoo_every_selection_in_exactly_one_slot() {
    // `build_with_bins` over random selection sets: every inserted
    // element occupies exactly one bin-or-stash slot (never zero, never
    // two), and `locate` agrees with the physical placement.
    for seed in 1100..1140u64 {
        let mut rng = Rng::new(seed);
        let k = 1 + rng.gen_range(250) as usize;
        let m = (k as u64) * 8;
        let params = random_params(&mut rng);
        // A client may select fewer than the session's k elements but
        // must still use the session's bin count.
        let take = 1 + rng.gen_range(k as u64) as usize;
        let elements = rng.sample_distinct(take, m);
        let num_bins = params.num_bins(k);
        let Ok(table) = CuckooTable::build_with_bins(&elements, num_bins, &params, &mut rng)
        else {
            continue; // rare failure with tight random ε — skip
        };
        assert_eq!(table.num_bins(), num_bins, "seed {seed}");
        let occupied = table.bins().iter().flatten().count();
        assert_eq!(
            occupied + table.stash().len(),
            elements.len(),
            "seed {seed}: slot count"
        );
        for &e in &elements {
            let in_bins = table.bins().iter().filter(|b| **b == Some(e)).count();
            let in_stash = table.stash().iter().filter(|&&s| s == e).count();
            assert_eq!(in_bins + in_stash, 1, "seed {seed}: element {e}");
            match table.locate(e).expect("inserted element locatable") {
                Ok(bin) => {
                    assert_eq!(table.bins()[bin], Some(e), "seed {seed}");
                    assert!(table.candidate_bins(e).contains(&bin), "seed {seed}");
                }
                Err(slot) => assert_eq!(table.stash()[slot], e, "seed {seed}"),
            }
        }
    }
}

#[test]
fn cuckoo_eviction_cycles_fill_the_stash_then_error() {
    // Deterministic eviction-cycle construction: find elements whose η=2
    // candidate bins are the SAME two bins. Three such elements cannot
    // all fit in two bins — the third must land in the stash; with the
    // stash full, insertion must surface CuckooError (never panic).
    let params = CuckooParams {
        epsilon: 1.0,
        eta: 2,
        sigma: 1,
        hash_seed: 11,
        max_kicks: 100,
    };
    let num_bins = 8;
    let probe = CuckooTable::build_with_bins(&[], num_bins, &params, &mut Rng::new(0)).unwrap();
    let mut groups: std::collections::HashMap<Vec<usize>, Vec<u64>> =
        std::collections::HashMap::new();
    for u in 0..4096u64 {
        let mut c = probe.candidate_bins(u);
        c.sort_unstable();
        if c.len() == 2 {
            groups.entry(c).or_default().push(u);
        }
    }
    let cycle: &Vec<u64> = groups
        .values()
        .find(|v| v.len() >= 4)
        .expect("4096 probes over 8 bins must yield 4 elements sharing a bin pair");

    // 3 elements into their 2 shared bins, σ = 1: exactly one stashed,
    // all locatable.
    let t = CuckooTable::build_with_bins(&cycle[..3], num_bins, &params, &mut Rng::new(1)).unwrap();
    assert_eq!(t.stash().len(), 1);
    for &e in &cycle[..3] {
        assert!(t.locate(e).is_some(), "lost {e}");
    }

    // 4 elements, σ = 1: the stash overflows — an Err, not a panic, and
    // the reported homeless element is one of ours.
    let err = CuckooTable::build_with_bins(&cycle[..4], num_bins, &params, &mut Rng::new(2))
        .expect_err("stash overflow must be reported");
    assert!(cycle[..4].contains(&err.element), "reported {}", err.element);

    // σ = 0: even the third element has nowhere to go.
    let p0 = CuckooParams { sigma: 0, ..params };
    assert!(CuckooTable::build_with_bins(&cycle[..3], num_bins, &p0, &mut Rng::new(3)).is_err());
}

#[test]
fn prop_duplicate_selections_follow_the_summing_convention() {
    // PR 2's convention, seed-swept: SSA sums the deltas of duplicate
    // selections (additivity), PSR retrieves per occurrence — and neither
    // path lets duplicates fight for cuckoo bins.
    for seed in 1200..1215u64 {
        let mut rng = Rng::new(seed);
        let m = 256 + rng.gen_range(1024);
        let base = rng.sample_distinct(8, m);
        // Sample 24 indices WITH replacement from the 8-element base:
        // heavy duplication guaranteed.
        let sel: Vec<u64> = (0..24)
            .map(|_| base[rng.gen_range(8) as usize])
            .collect();
        let session = Session::new_full(SessionParams {
            m,
            k: 24,
            cuckoo: CuckooParams::default().with_seed(seed),
        });

        // SSA: duplicate deltas must sum.
        let deltas: Vec<u64> = (0..24).map(|_| rng.next_u64()).collect();
        let mut expected = vec![0u64; m as usize];
        for (&u, &d) in sel.iter().zip(&deltas) {
            expected[u as usize] = expected[u as usize].wrapping_add(d);
        }
        let batch = ssa::client_update(&session, &sel, &deltas, &mut rng).unwrap();
        let dw = ssa::reconstruct(
            &ssa::server_aggregate(&session, &[batch.server_keys(0)]),
            &ssa::server_aggregate(&session, &[batch.server_keys(1)]),
        );
        assert_eq!(dw, expected, "seed {seed} (SSA)");

        // PSR: every occurrence retrieves its weight.
        let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
        let (ctx, qbatch) = psr::client_query::<u64>(&session, &sel, &mut rng).unwrap();
        let engine = RetrievalEngine::new(2);
        let a0 = engine.answer_keys(&session, &weights, &qbatch.server_keys(0));
        let a1 = engine.answer_keys(&session, &weights, &qbatch.server_keys(1));
        let got = psr::client_reconstruct(&ctx, session.simple.num_bins(), &sel, &a0, &a1);
        for (i, &u) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[u as usize], "seed {seed} occurrence {i} (PSR)");
        }
    }
}

#[test]
fn prop_engine_forms_and_widths_agree() {
    // The unified engine must produce bit-identical share vectors across
    // worker counts and across its two DPF input forms (materialised keys
    // vs zero-copy publics + master seed), including sessions with an
    // occupied stash (σ > 0).
    use fsl::protocol::aggregate::{uploads_of, AggregationEngine};
    for seed in 700..715u64 {
        let mut rng = Rng::new(seed);
        let m = 128 + rng.gen_range(2048);
        let k = ((1 + rng.gen_range(32)) as usize).min(m as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: random_params(&mut rng),
        });
        let n = 1 + rng.gen_range(4) as usize;
        let mut batches = Vec::new();
        let mut ok = true;
        for _ in 0..n {
            let sel = rng.sample_distinct(k, m);
            let dl: Vec<u64> = sel.iter().map(|_| rng.next_u64()).collect();
            match ssa::client_update(&session, &sel, &dl, &mut rng) {
                Ok(b) => batches.push(b),
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue; // rare cuckoo failure with tight random ε — skip
        }
        for party in 0..2u8 {
            let keys: Vec<_> = batches.iter().map(|b| b.server_keys(party)).collect();
            let serial = AggregationEngine::serial().aggregate_keys(&session, &keys);
            for threads in [2usize, 3, 64] {
                assert_eq!(
                    AggregationEngine::new(threads).aggregate_keys(&session, &keys),
                    serial,
                    "seed {seed} party {party} threads {threads}"
                );
            }
            let uploads = uploads_of(&batches, party);
            assert_eq!(
                AggregationEngine::new(4).aggregate_publics(&session, party, &uploads),
                serial,
                "seed {seed} party {party} publics form"
            );
        }
    }
}
