//! Property-style randomized sweeps over the protocol invariants.
//!
//! No proptest crate is available offline, so these are seed-swept
//! properties: each test draws many random configurations (η, ε, σ, m, k,
//! payload group) and asserts the protocol invariants hold for all of
//! them. Failures print the offending seed for reproduction.

use fsl::crypto::rng::Rng;
use fsl::group::{Group, MegaElem};
use fsl::hashing::{CuckooParams, CuckooTable};
use fsl::protocol::{mega, psr, psu, ssa, Session, SessionParams};

fn random_params(rng: &mut Rng) -> CuckooParams {
    CuckooParams {
        epsilon: 1.2 + rng.gen_f64() * 0.4,
        eta: 2 + rng.gen_range(3) as usize, // 2..=4
        sigma: if rng.gen_f64() < 0.3 { 4 } else { 0 },
        hash_seed: rng.next_u64(),
        max_kicks: 500,
    }
}

#[test]
fn prop_psr_always_correct() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let m = 256 + rng.gen_range(4096);
        let k = (1 + rng.gen_range(64)) as usize;
        let k = k.min(m as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: random_params(&mut rng),
        });
        let weights: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
        let sel = rng.sample_distinct(k, m);
        let Ok((ctx, batch)) = psr::client_query::<u64>(&session, &sel, &mut rng) else {
            continue; // rare cuckoo failure with tight random ε — skip
        };
        let a0 = psr::server_answer(&session, &weights, &batch.server_keys(0));
        let a1 = psr::server_answer(&session, &weights, &batch.server_keys(1));
        let got = psr::client_reconstruct(&ctx, session.simple.num_bins(), &sel, &a0, &a1);
        for (i, &s) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[s as usize], "seed {seed} sel {s}");
        }
    }
}

#[test]
fn prop_ssa_sums_match_plaintext() {
    for seed in 100..130u64 {
        let mut rng = Rng::new(seed);
        let m = 128 + rng.gen_range(2048);
        let k = ((1 + rng.gen_range(32)) as usize).min(m as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: random_params(&mut rng),
        });
        let n = 1 + rng.gen_range(5) as usize;
        let mut expected = vec![0u64; m as usize];
        let mut keys0 = Vec::new();
        let mut keys1 = Vec::new();
        let mut ok = true;
        for _ in 0..n {
            let sel = rng.sample_distinct(k, m);
            let dl: Vec<u64> = sel.iter().map(|_| rng.next_u64()).collect();
            match ssa::client_update(&session, &sel, &dl, &mut rng) {
                Ok(batch) => {
                    for (&i, &d) in sel.iter().zip(&dl) {
                        expected[i as usize] = expected[i as usize].wrapping_add(d);
                    }
                    keys0.push(batch.server_keys(0));
                    keys1.push(batch.server_keys(1));
                }
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        let dw = ssa::reconstruct(
            &ssa::server_aggregate(&session, &keys0),
            &ssa::server_aggregate(&session, &keys1),
        );
        assert_eq!(dw, expected, "seed {seed}");
    }
}

#[test]
fn prop_ssa_mega_elements() {
    for seed in 200..215u64 {
        let mut rng = Rng::new(seed);
        let rows = 64 + rng.gen_range(512);
        let k = ((1 + rng.gen_range(16)) as usize).min(rows as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m: rows,
            k,
            cuckoo: CuckooParams::default().with_seed(seed),
        });
        let sel = rng.sample_distinct(k, rows);
        let dl: Vec<MegaElem<6>> = sel
            .iter()
            .map(|_| {
                let mut e = [0u64; 6];
                for v in &mut e {
                    *v = rng.next_u64();
                }
                MegaElem(e)
            })
            .collect();
        let batch = ssa::client_update(&session, &sel, &dl, &mut rng).unwrap();
        let dw = ssa::reconstruct(
            &ssa::server_aggregate(&session, &[batch.server_keys(0)]),
            &ssa::server_aggregate(&session, &[batch.server_keys(1)]),
        );
        for (pos, val) in dw.iter().enumerate() {
            match sel.iter().position(|&s| s == pos as u64) {
                Some(i) => assert_eq!(*val, dl[i], "seed {seed}"),
                None => assert_eq!(*val, MegaElem::zero(), "seed {seed}"),
            }
        }
    }
}

#[test]
fn prop_mega_group_roundtrip() {
    for seed in 300..340u64 {
        let mut rng = Rng::new(seed);
        let m = 1 + rng.gen_range(500) as usize;
        let w: Vec<u64> = (0..m).map(|_| rng.next_u64()).collect();
        let g = mega::group_weights::<7>(&w);
        assert_eq!(mega::ungroup_weights(&g, m), w, "seed {seed} m {m}");
    }
}

#[test]
fn prop_psu_equals_set_union() {
    for seed in 400..420u64 {
        let mut rng = Rng::new(seed);
        let m = 512 + rng.gen_range(8192);
        let k = (4 + rng.gen_range(32)) as usize;
        let n = 2 + rng.gen_range(6) as usize;
        let key = rng.gen_seed();
        let sets: Vec<Vec<u64>> = (0..n)
            .map(|_| {
                let take = 1 + rng.gen_range(k as u64 - 1) as usize;
                rng.sample_distinct(take, m)
            })
            .collect();
        let mut expected: Vec<u64> = sets.iter().flatten().copied().collect();
        expected.sort_unstable();
        expected.dedup();
        assert_eq!(psu::run_psu(&key, m, k, &sets, &mut rng), expected, "seed {seed}");
    }
}

#[test]
fn prop_cuckoo_locate_total() {
    // Every inserted element is locatable; every absent element is not.
    for seed in 500..540u64 {
        let mut rng = Rng::new(seed);
        let k = 1 + rng.gen_range(300) as usize;
        let m = (k as u64) * 8;
        let params = random_params(&mut rng);
        let elements = rng.sample_distinct(k, m);
        let Ok(table) = CuckooTable::build(&elements, &params, &mut rng) else {
            continue;
        };
        for &e in &elements {
            assert!(table.locate(e).is_some(), "seed {seed} lost {e}");
        }
        for probe in 0..20 {
            let x = m + probe; // guaranteed absent
            assert!(table.locate(x).is_none(), "seed {seed} ghost {x}");
        }
    }
}

#[test]
fn prop_dpf_key_sizes_follow_formula() {
    use fsl::dpf::{gen, DpfKey};
    for seed in 600..640u64 {
        let mut rng = Rng::new(seed);
        let depth = 1 + rng.gen_range(16) as usize;
        let alpha = rng.gen_range(1 << depth);
        let (k0, _k1) = gen::<u128>(depth, alpha, &7u128, rng.gen_seed(), rng.gen_seed());
        assert_eq!(k0.size_bits(), depth * 130 + 128 + 128, "seed {seed}");
        let bytes = k0.to_bytes();
        let parsed = DpfKey::<u128>::from_bytes(&bytes).unwrap();
        assert_eq!(parsed.to_bytes(), bytes);
    }
}

#[test]
fn prop_engine_forms_and_widths_agree() {
    // The unified engine must produce bit-identical share vectors across
    // worker counts and across its two DPF input forms (materialised keys
    // vs zero-copy publics + master seed), including sessions with an
    // occupied stash (σ > 0).
    use fsl::protocol::aggregate::{AggregationEngine, PublicsUpload};
    for seed in 700..715u64 {
        let mut rng = Rng::new(seed);
        let m = 128 + rng.gen_range(2048);
        let k = ((1 + rng.gen_range(32)) as usize).min(m as usize / 4).max(1);
        let session = Session::new_full(SessionParams {
            m,
            k,
            cuckoo: random_params(&mut rng),
        });
        let n = 1 + rng.gen_range(4) as usize;
        let mut batches = Vec::new();
        let mut ok = true;
        for _ in 0..n {
            let sel = rng.sample_distinct(k, m);
            let dl: Vec<u64> = sel.iter().map(|_| rng.next_u64()).collect();
            match ssa::client_update(&session, &sel, &dl, &mut rng) {
                Ok(b) => batches.push(b),
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue; // rare cuckoo failure with tight random ε — skip
        }
        for party in 0..2u8 {
            let keys: Vec<_> = batches.iter().map(|b| b.server_keys(party)).collect();
            let serial = AggregationEngine::serial().aggregate_keys(&session, &keys);
            for threads in [2usize, 3, 64] {
                assert_eq!(
                    AggregationEngine::new(threads).aggregate_keys(&session, &keys),
                    serial,
                    "seed {seed} party {party} threads {threads}"
                );
            }
            let uploads: Vec<PublicsUpload<'_, u64>> = batches
                .iter()
                .map(|b| PublicsUpload {
                    publics: &b.publics,
                    msk: &b.msk[party as usize],
                })
                .collect();
            assert_eq!(
                AggregationEngine::new(4).aggregate_publics(&session, party, &uploads),
                serial,
                "seed {seed} party {party} publics form"
            );
        }
    }
}
