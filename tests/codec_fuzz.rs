//! Sustained, seeded fuzzing of every codec an adversary can reach off
//! the wire — the control plane (`ServerCmd`/`ServerReply`), the
//! transport handshake (`Hello`/`HelloAck`), the session codec, and the
//! recovery snapshot — plus typed-error checks that a crashed or silent
//! server surfaces a [`TransportError`] within its deadline on both
//! transports.
//!
//! Every test is deterministic: cases derive from a fixed seed via
//! [`fsl::fuzz::Fuzzer`], and the per-test case count is bounded (CI
//! smoke sets `FSL_FUZZ_CASES` low; local soaks raise it). The decoder
//! contract under fuzz is narrow and absolute: *never* panic, *never*
//! misparse a strict prefix as complete, and anything that decodes `Ok`
//! must re-encode to a stable fixed point.

use fsl::coordinator::snapshot::ServerSnapshot;
use fsl::coordinator::wire::{self, ServerCmd, ServerReply};
use fsl::coordinator::{ClientOutcome, VerifiedSsaResult};
use fsl::crypto::field::Fp;
use fsl::fuzz::Fuzzer;
use fsl::hashing::CuckooParams;
use fsl::net;
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions, TcpTransport};
use fsl::net::transport::{Hello, HelloAck, Role, Transport, TransportError};
use fsl::protocol::{Session, SessionParams};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn small_session() -> Session {
    Session::new_full(SessionParams {
        m: 256,
        k: 8,
        cuckoo: CuckooParams::default().with_seed(5),
    })
}

/// Valid encodings of every command variant the codec supports.
fn cmd_corpus() -> Vec<Vec<u8>> {
    let cmds: Vec<ServerCmd<u64>> = vec![
        ServerCmd::Ssa {
            n: 3,
            deadline_nanos: 0,
        },
        ServerCmd::Psr {
            n: 2,
            deadline_nanos: 250_000_000,
        },
        ServerCmd::UdpfSetup {
            n: 4,
            deadline_nanos: 1,
        },
        ServerCmd::UdpfEpoch {
            n: 4,
            epoch: 7,
            deadline_nanos: 9,
        },
        ServerCmd::VerifiedSsa {
            uploads: Arc::new(Vec::new()),
            seed: 99,
        },
        ServerCmd::PsuAlign {
            n: 5,
            shuffle_seed: 3,
        },
        ServerCmd::SetWeights(Arc::new(vec![1u64, 2, 3, u64::MAX])),
        ServerCmd::SetSession(Arc::new(small_session())),
        ServerCmd::Ping,
        ServerCmd::DialPeer {
            addr: "127.0.0.1:7100".into(),
        },
        ServerCmd::Shutdown,
    ];
    cmds.iter().map(wire::encode_cmd).collect()
}

/// Valid encodings of every reply shape a driver can receive.
fn reply_corpus() -> Vec<Vec<u8>> {
    let replies: Vec<ServerReply<u64>> = vec![
        ServerReply::Ack,
        ServerReply::Round {
            server_time: Duration::from_micros(1234),
            delta: None,
            inter_sent: 77,
            outcomes: Vec::new(),
        },
        ServerReply::Round {
            server_time: Duration::from_millis(5),
            delta: Some(vec![0u64, 1, u64::MAX]),
            inter_sent: 0,
            outcomes: vec![
                ClientOutcome::Completed,
                ClientOutcome::Dropped,
                ClientOutcome::StragglerCut,
            ],
        },
        ServerReply::Verified {
            result: VerifiedSsaResult {
                delta: vec![Fp::new(3), Fp::new(4)],
                rejected: vec![1, 7],
            },
            server_time: Duration::from_millis(5),
        },
        ServerReply::Failed("engine exploded".into()),
    ];
    replies.iter().map(wire::encode_reply).collect()
}

/// Fuzz one decoder against mutations of a corpus: decoding must never
/// panic, and whatever decodes `Ok` must re-encode to a fixed point
/// (encode ∘ decode is idempotent on accepted inputs).
fn fuzz_codec(
    seed: u64,
    corpus: &[Vec<u8>],
    decode_encode: impl Fn(&[u8]) -> Option<Vec<u8>>,
    what: &str,
) {
    let mut f = Fuzzer::new(seed);
    let cases = Fuzzer::cases_from_env(400);
    for round in 0..cases {
        for base in corpus {
            let mutated = f.mutate(base);
            if let Some(reencoded) = decode_encode(&mutated) {
                let again = decode_encode(&reencoded).unwrap_or_else(|| {
                    panic!("{what}: accepted bytes failed to re-decode (seed {seed}, case {round})")
                });
                assert_eq!(
                    again, reencoded,
                    "{what}: re-encoding is not a fixed point (seed {seed}, case {round})"
                );
            }
        }
        // Pure garbage alongside the structured mutations.
        let garbage = f.blob(96);
        let _ = decode_encode(&garbage);
    }
}

#[test]
fn command_codec_survives_sustained_mutation() {
    fuzz_codec(
        0xC0DEC_01,
        &cmd_corpus(),
        |bytes| {
            wire::decode_cmd::<u64>(bytes)
                .ok()
                .map(|cmd| wire::encode_cmd(&cmd))
        },
        "decode_cmd",
    );
}

#[test]
fn reply_codec_survives_sustained_mutation() {
    fuzz_codec(
        0xC0DEC_02,
        &reply_corpus(),
        |bytes| {
            wire::decode_reply::<u64>(bytes)
                .ok()
                .map(|reply| wire::encode_reply(&reply))
        },
        "decode_reply",
    );
}

#[test]
fn session_codec_survives_sustained_mutation() {
    let full = wire::encode_session(&small_session());
    let union = wire::encode_session(
        &Session::new_union(
            SessionParams {
                m: 1 << 20,
                k: 4,
                cuckoo: CuckooParams::default().with_seed(9),
            },
            vec![3, 17, 99, 4096, 70_000],
        )
        .expect("valid union session"),
    );
    fuzz_codec(
        0xC0DEC_03,
        &[full, union],
        |bytes| {
            wire::decode_session(bytes)
                .ok()
                .map(|s| wire::encode_session(&s))
        },
        "decode_session",
    );
}

#[test]
fn handshake_codecs_round_trip_and_survive_mutation() {
    let hellos = vec![
        Hello {
            party: 0,
            role: Role::Control {
                max_clients: 8,
                m: 1 << 15,
                k: 512,
                group: "u64".into(),
            },
        },
        Hello {
            party: 1,
            role: Role::Client { id: 3 },
        },
        Hello {
            party: 0,
            role: Role::Peer,
        },
    ];
    let acks = vec![
        HelloAck {
            party: 1,
            error: None,
        },
        HelloAck {
            party: 0,
            error: Some("group mismatch: driver sent u128".into()),
        },
    ];
    for h in &hellos {
        assert_eq!(&Hello::decode(&h.encode()).unwrap(), h);
    }
    for a in &acks {
        assert_eq!(&HelloAck::decode(&a.encode()).unwrap(), a);
    }
    fuzz_codec(
        0xC0DEC_04,
        &hellos.iter().map(Hello::encode).collect::<Vec<_>>(),
        |bytes| Hello::decode(bytes).ok().map(|h| h.encode()),
        "Hello::decode",
    );
    fuzz_codec(
        0xC0DEC_05,
        &acks.iter().map(HelloAck::encode).collect::<Vec<_>>(),
        |bytes| HelloAck::decode(bytes).ok().map(|a| a.encode()),
        "HelloAck::decode",
    );
}

#[test]
fn every_strict_prefix_of_a_control_message_is_an_error() {
    for bytes in cmd_corpus() {
        for cut in 0..bytes.len() {
            assert!(
                wire::decode_cmd::<u64>(&bytes[..cut]).is_err(),
                "cmd prefix {cut}/{} decoded",
                bytes.len()
            );
        }
    }
    for bytes in reply_corpus() {
        for cut in 0..bytes.len() {
            assert!(
                wire::decode_reply::<u64>(&bytes[..cut]).is_err(),
                "reply prefix {cut}/{} decoded",
                bytes.len()
            );
        }
    }
}

#[test]
fn snapshot_mutations_are_rejected_outright() {
    // Unlike the control plane (where a flipped payload byte is a
    // different-but-valid message), the snapshot is hash-protected:
    // *every* mutation must be rejected, not just truncations.
    let snap = ServerSnapshot::<u64> {
        party: 1,
        group: std::any::type_name::<u64>().to_string(),
        session: wire::encode_session(&small_session()),
        udpf_total: 3,
        udpf: Vec::new(),
        dead: vec![false, true, false],
    };
    let bytes = snap.encode();
    assert!(ServerSnapshot::<u64>::decode(&bytes).is_ok());
    let mut f = Fuzzer::new(0xC0DEC_06);
    let cases = Fuzzer::cases_from_env(400);
    for round in 0..cases {
        let mutated = f.mutate(&bytes);
        let err = ServerSnapshot::<u64>::decode(&mutated)
            .err()
            .unwrap_or_else(|| panic!("mutated snapshot accepted (case {round})"));
        assert!(!err.to_string().is_empty());
        let garbage = f.blob(128);
        if garbage != bytes {
            assert!(ServerSnapshot::<u64>::decode(&garbage).is_err());
        }
    }
}

// ---- typed failure surfacing (both transports) -------------------------

#[test]
fn inproc_silence_and_disconnect_are_typed() {
    let (a, b) = net::pair(Duration::ZERO);
    let err = a.recv_timeout(Duration::from_millis(30)).unwrap_err();
    assert!(TransportError::is_timeout(&err), "not typed Timeout: {err:?}");
    drop(b);
    let err = a.recv_timeout(Duration::from_millis(30)).unwrap_err();
    assert!(TransportError::is_closed(&err), "not typed Closed: {err:?}");
}

#[test]
fn tcp_crash_surfaces_typed_errors_within_the_deadline() {
    let acceptor = TcpAcceptor::bind("127.0.0.1:0", TcpOptions::default()).unwrap();
    let addr = acceptor.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        use fsl::net::transport::Listener;
        let (conn, _hello) = acceptor.accept().expect("accept");
        conn.send(HelloAck { party: 1, error: None }.encode())
            .expect("ack");
        // Stay silent long enough for the client's timeout probe, then
        // "crash" by dropping the connection.
        std::thread::sleep(Duration::from_millis(200));
        drop(conn);
    });
    let conn = TcpTransport::connect(
        addr.as_str(),
        &Hello {
            party: 1,
            role: Role::Peer,
        },
        &TcpOptions::default(),
    )
    .unwrap();

    // Silent server: typed Timeout, and promptly — the caller's deadline
    // is the bound, not some internal retry loop.
    let t0 = Instant::now();
    let err = conn.recv_timeout(Duration::from_millis(50)).unwrap_err();
    assert!(TransportError::is_timeout(&err), "not typed Timeout: {err:?}");
    assert!(
        t0.elapsed() < Duration::from_millis(2000),
        "timeout took {:?}",
        t0.elapsed()
    );

    // Crashed server: typed Closed well before the (long) deadline.
    let t0 = Instant::now();
    let err = conn.recv_timeout(Duration::from_secs(30)).unwrap_err();
    assert!(TransportError::is_closed(&err), "not typed Closed: {err:?}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "close detection took {:?}",
        t0.elapsed()
    );
    server.join().unwrap();
}
