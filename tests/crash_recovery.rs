//! Crash/recovery regressions: `fsl serve` processes are killed with
//! SIGKILL mid-U-DPF-session and restarted from their snapshots, after
//! which the next rounds must be bit-identical to an uninterrupted
//! deployment. A corrupt snapshot must be a typed startup rejection, and
//! a killed server must surface a typed transport error to the driver.
//!
//! These tests drive the real binary (`CARGO_BIN_EXE_fsl`) over real TCP
//! sockets — three processes per scenario, exactly like the CI `faults`
//! job — with ephemeral ports announced on the children's stdout.

use fsl::coordinator::snapshot::ServerSnapshot;
use fsl::coordinator::{wire, FslRuntime, FslRuntimeBuilder, KeyMode};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::net::transport::TransportError;
use fsl::protocol::{Session, SessionParams};
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

const N: usize = 4;
const M: u64 = 1 << 9;
const K: usize = 8;

fn session() -> Session {
    Session::new_full(SessionParams {
        m: M,
        k: K,
        cuckoo: CuckooParams::default().with_seed(21),
    })
}

/// Fixed selections, per-epoch deltas (the U-DPF contract).
fn clients(epoch: u64) -> Vec<(Vec<u64>, Vec<u64>)> {
    let mut rng = Rng::new(909);
    (0..N)
        .map(|_| {
            let sel = rng.sample_distinct(K, M);
            let dl: Vec<u64> = sel.iter().map(|&x| x + 1 + epoch).collect();
            (sel, dl)
        })
        .collect()
}

/// The plaintext aggregate every epoch must reconstruct to.
fn full_sum(clients: &[(Vec<u64>, Vec<u64>)]) -> Vec<u64> {
    let mut expected = vec![0u64; M as usize];
    for (sel, dl) in clients {
        for (&x, &d) in sel.iter().zip(dl) {
            expected[x as usize] = expected[x as usize].wrapping_add(d);
        }
    }
    expected
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fsl-crash-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

struct Server {
    child: Child,
    addr: String,
}

/// Start one `fsl serve` process on an ephemeral port and parse the bound
/// address from its announce line.
fn spawn_server(party: u8, snapshot: &Path) -> Server {
    let mut child = Command::new(env!("CARGO_BIN_EXE_fsl"))
        .args([
            "serve",
            &format!("party={party}"),
            "listen=127.0.0.1:0",
            &format!("snapshot={}", snapshot.display()),
            "threads=1",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn fsl serve");
    let mut line = String::new();
    BufReader::new(child.stdout.take().unwrap())
        .read_line(&mut line)
        .expect("read announce line");
    let addr = line.trim().rsplit(' ').next().unwrap_or_default().to_string();
    assert!(addr.contains(':'), "unexpected announce line: {line:?}");
    Server { child, addr }
}

impl Server {
    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    fn wait(mut self) {
        let status = self.child.wait().expect("server did not exit");
        assert!(status.success(), "server exited with {status}");
    }
}

fn udpf_builder() -> FslRuntimeBuilder {
    FslRuntimeBuilder::from_session(session())
        .threads(1)
        .max_clients(N)
        .key_mode(KeyMode::Udpf)
        .reply_timeout(Duration::from_secs(120))
        .connect_retry(Duration::from_secs(30))
}

#[test]
fn killed_servers_recover_their_udpf_deployment_from_snapshots() {
    let dir = temp_dir("recover");
    let snap0 = dir.join("s0.snap");
    let snap1 = dir.join("s1.snap");
    let _ = std::fs::remove_file(&snap0);
    let _ = std::fs::remove_file(&snap1);

    let s0 = spawn_server(0, &snap0);
    let s1 = spawn_server(1, &snap1);
    let mut rt: FslRuntime<u64> = udpf_builder().connect(&s0.addr, &s1.addr).unwrap();

    // The uninterrupted reference: same session and updates, in-proc,
    // never crashed. Its per-epoch deltas are the bit-exact target.
    let mut reference = FslRuntimeBuilder::from_session(session())
        .threads(1)
        .max_clients(N)
        .key_mode(KeyMode::Udpf)
        .build::<u64>()
        .unwrap();
    let mut rng = Rng::new(11);
    let mut ref_rng = Rng::new(12);

    // Snapshots are written on every epoch boundary before the reply is
    // acked: durable after the setup round, rewritten by the hint round.
    let mut after_setup = Vec::new();
    for epoch in 0..2u64 {
        let cs = clients(epoch);
        let out = rt.ssa(&cs, &mut rng).unwrap();
        let ref_out = reference.ssa(&cs, &mut ref_rng).unwrap();
        assert_eq!(out.delta, ref_out.delta, "pre-crash epoch {epoch}");
        if epoch == 0 {
            after_setup = std::fs::read(&snap0).expect("S0 snapshot missing after setup");
            assert!(snap1.exists(), "S1 snapshot missing after setup");
        }
    }
    assert_ne!(
        std::fs::read(&snap0).unwrap(),
        after_setup,
        "S0 snapshot was not rewritten by the hint round"
    );

    // SIGKILL both servers mid-session. (The deployment is a pair: S0's
    // in-flight round state references S1's, so recovery restarts both.)
    s0.kill();
    s1.kill();

    // The driver's next round must fail with a *typed* transport error,
    // not hang or panic.
    let err = rt.ssa(&clients(2), &mut rng).unwrap_err();
    assert!(
        TransportError::of(&err).is_some(),
        "server crash surfaced an untyped error: {err:?}"
    );

    // Restart from the snapshots on fresh ports (the old ones may sit in
    // TIME_WAIT) and re-dial, carrying the driver's retained U-DPF state
    // into the new runtime.
    let s0 = spawn_server(0, &snap0);
    let s1 = spawn_server(1, &snap1);
    let state = rt.export_udpf_state();
    drop(rt);
    let mut rt: FslRuntime<u64> = udpf_builder().connect(&s0.addr, &s1.addr).unwrap();
    rt.resume_udpf(state).unwrap();

    // The interrupted epoch reruns, then the session continues — both
    // bit-identical to the uninterrupted reference.
    for epoch in 2..4u64 {
        let cs = clients(epoch);
        let out = rt.ssa(&cs, &mut rng).unwrap();
        let ref_out = reference.ssa(&cs, &mut ref_rng).unwrap();
        assert_eq!(
            out.delta, ref_out.delta,
            "post-recovery epoch {epoch} is not bit-identical to the \
             uninterrupted session"
        );
        assert_eq!(out.delta, full_sum(&cs), "post-recovery epoch {epoch} aggregate");
    }

    rt.shutdown().unwrap();
    reference.shutdown().unwrap();
    s0.wait();
    s1.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_corrupt_snapshot_is_a_typed_startup_rejection() {
    let dir = temp_dir("corrupt");
    let snap = dir.join("s0.snap");
    let good = ServerSnapshot::<u64> {
        party: 0,
        group: std::any::type_name::<u64>().to_string(),
        session: wire::encode_session(&session()),
        udpf_total: 0,
        udpf: Vec::new(),
        dead: vec![false; N],
    };
    good.write(&snap).unwrap();
    assert!(ServerSnapshot::<u64>::load(&snap).is_ok());

    // Flip one byte in the middle: the content hash must catch it.
    let mut bytes = std::fs::read(&snap).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&snap, &bytes).unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_fsl"))
        .args([
            "serve",
            "party=0",
            "listen=127.0.0.1:0",
            &format!("snapshot={}", snap.display()),
        ])
        .output()
        .expect("run fsl serve");
    assert!(
        !out.status.success(),
        "a server restored from a corrupt snapshot"
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("restoring server state"),
        "rejection did not name the snapshot restore: {stderr}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
