//! Scale-harness integration: the multiplexed server path end-to-end
//! over real loopback sockets. Covers accept-phase churn (lanes dialed
//! before control, strays, overlaps, duplicate uploads — the delta must
//! be bit-identical to an orderly deployment), the `fsl loadgen` driver
//! with in-process verification, and the two fault planes: a straggler
//! cohort must be cut at the deadline rather than extend the round, and
//! a severed lane must classify its unsent tail as dropped.

use fsl::coordinator::wire::{self, ServerCmd, ServerReply};
use fsl::coordinator::{
    run_loadgen, serve, ClientOutcome, LoadgenOptions, LoadgenVerify, ServeOptions,
};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions, TcpTransport};
use fsl::net::transport::{Hello, Role, Transport};
use fsl::protocol::{msg, ssa, Session, SessionParams};
use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

fn session(m: u64, k: usize, seed: u64) -> Session {
    Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default().with_seed(seed),
    })
}

/// Spawn one standalone server on an ephemeral loopback port, exactly as
/// `fsl serve` would run it.
fn spawn_server(party: u8) -> (String, JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || {
        let acceptor = TcpAcceptor::new(listener, TcpOptions::default());
        let mut opts = ServeOptions::new(party);
        opts.threads = 1;
        serve::<u64>(&acceptor, &opts)
    });
    (addr, handle)
}

/// Per-virtual-client inputs, deterministic in `vid` alone so the two
/// deployments of the churn test feed bit-identical uploads.
fn churn_inputs(session: &Session, n: u32) -> Vec<(Vec<u64>, Vec<u64>)> {
    (0..n)
        .map(|vid| {
            let mut rng = Rng::new(0xC0FFEE ^ u64::from(vid));
            let sel = rng.sample_distinct(session.params.k, session.params.m);
            let dl = sel.iter().map(|&x| x * 3 + 1).collect();
            (sel, dl)
        })
        .collect()
}

fn expect_ack(ctrl: &TcpTransport, what: &str) {
    let raw = ctrl
        .recv_timeout(Duration::from_secs(30))
        .unwrap_or_else(|e| panic!("{what}: {e:#}"));
    match wire::decode_reply::<u64>(&raw).expect(what) {
        ServerReply::Ack => {}
        ServerReply::Failed(e) => panic!("{what}: server failed: {e}"),
        _ => panic!("{what}: unexpected reply kind"),
    }
}

fn round_reply(ctrl: &TcpTransport, who: &str) -> (Option<Vec<u64>>, Vec<ClientOutcome>) {
    let raw = ctrl
        .recv_timeout(Duration::from_secs(60))
        .unwrap_or_else(|e| panic!("{who} round reply: {e:#}"));
    match wire::decode_reply::<u64>(&raw).expect(who) {
        ServerReply::Round { delta, outcomes, .. } => (delta, outcomes),
        ServerReply::Failed(e) => panic!("{who} round failed: {e}"),
        _ => panic!("{who}: unexpected reply kind"),
    }
}

/// `[vid u32 LE][payload]` — the mux lanes' framing contract.
fn lane_frame(vid: u32, payload: Vec<u8>) -> Vec<u8> {
    let mut out = vid.to_le_bytes().to_vec();
    out.extend_from_slice(&payload);
    out
}

/// Drive one full mux round against two freshly spawned servers and
/// return S0's reconstructed delta. `scrambled` switches the deployment
/// from an orderly one (control, then lanes in order, each upload once)
/// to the churn path: lanes dialed before control (parked), a stray
/// connection spraying garbage, an overlapping lane rejected mid-accept,
/// uploads sent in reverse vid order and every frame twice.
fn drive_mux_round(
    session: &Session,
    inputs: &[(Vec<u64>, Vec<u64>)],
    scrambled: bool,
) -> Vec<u64> {
    let n = inputs.len();
    let n_wire = u32::try_from(n).expect("cohort fits the wire");
    let half = n_wire / 2;
    let (addr0, h0) = spawn_server(0);
    let (addr1, h1) = spawn_server(1);
    let tcp = TcpOptions::default();
    let control = || Role::Control {
        max_clients: n_wire,
        m: session.params.m,
        k: session.params.k as u64,
        group: std::any::type_name::<u64>().to_string(),
    };
    let dial = |addr: &str, party: u8, role: Role| -> TcpTransport {
        TcpTransport::connect(addr, &Hello { party, role }, &TcpOptions::default())
            .unwrap_or_else(|e| panic!("dialling S{party}: {e:#}"))
    };
    // A pre-control lane parks server-side and is only acked once the
    // control link lands, so it must dial from its own thread.
    let parked_dial = |addr: &str, party: u8, role: Role| -> JoinHandle<TcpTransport> {
        let addr = addr.to_string();
        std::thread::spawn(move || {
            TcpTransport::connect(&addr[..], &Hello { party, role }, &TcpOptions::default())
                .unwrap_or_else(|e| panic!("parked dial to S{party}: {e:#}"))
        })
    };

    let (ctrl0, ctrl1, lane0_a, lane0_b, lane1_a, lane1_b);
    if scrambled {
        // Both of S1's lanes dial before its control link and park.
        let p1a = parked_dial(&addr1, 1, Role::ClientMux { lo: 0, count: half });
        let p1b = parked_dial(&addr1, 1, Role::ClientMux { lo: half, count: n_wire - half });
        std::thread::sleep(Duration::from_millis(100));
        ctrl1 = dial(&addr1, 1, control());
        lane1_a = p1a.join().expect("parked S1 lane a");
        lane1_b = p1b.join().expect("parked S1 lane b");

        // S0: one lane parks pre-control, a stray connection sprays
        // garbage (dropped silently), control lands, an overlapping lane
        // is rejected with a reasoned ack, the last lane completes
        // coverage.
        let p0b = parked_dial(&addr0, 0, Role::ClientMux { lo: half, count: n_wire - half });
        {
            use std::io::Write as _;
            let mut junk = std::net::TcpStream::connect(&addr0[..]).expect("stray connect");
            junk.write_all(b"\x00\x01 junk").expect("stray write");
        }
        std::thread::sleep(Duration::from_millis(100));
        ctrl0 = dial(&addr0, 0, control());
        lane0_b = p0b.join().expect("parked S0 lane b");
        let overlap = TcpTransport::connect(
            &addr0[..],
            &Hello { party: 0, role: Role::ClientMux { lo: half.saturating_sub(1), count: 2 } },
            &tcp,
        );
        let err = match overlap {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("overlapping lane must be rejected"),
        };
        assert!(err.contains("overlap"), "unexpected rejection: {err}");
        lane0_a = dial(&addr0, 0, Role::ClientMux { lo: 0, count: half });
    } else {
        ctrl1 = dial(&addr1, 1, control());
        lane1_a = dial(&addr1, 1, Role::ClientMux { lo: 0, count: half });
        lane1_b = dial(&addr1, 1, Role::ClientMux { lo: half, count: n_wire - half });
        ctrl0 = dial(&addr0, 0, control());
        lane0_a = dial(&addr0, 0, Role::ClientMux { lo: 0, count: half });
        lane0_b = dial(&addr0, 0, Role::ClientMux { lo: half, count: n_wire - half });
    }

    // Session install and round command, in the loadgen driver's order:
    // S1 first (it must be ready to dial the peer link), then the dial,
    // then S0 — whose accept phase only completes once the peer link is
    // in, so its ack doubles as a deployment barrier.
    let arc = Arc::new(session.clone());
    let set1 = wire::encode_cmd(&ServerCmd::<u64>::SetSession(Arc::clone(&arc)));
    ctrl1.send(set1).expect("SetSession S1");
    expect_ack(&ctrl1, "SetSession S1");
    let peer = wire::encode_cmd(&ServerCmd::<u64>::DialPeer { addr: addr0.clone() });
    ctrl1.send(peer).expect("DialPeer");
    expect_ack(&ctrl1, "DialPeer");
    let set0 = wire::encode_cmd(&ServerCmd::<u64>::SetSession(arc));
    ctrl0.send(set0).expect("SetSession S0");
    expect_ack(&ctrl0, "SetSession S0");
    let cmd = ServerCmd::<u64>::Ssa { n, deadline_nanos: 20_000_000_000 };
    ctrl1.send(wire::encode_cmd(&cmd)).expect("Ssa S1");
    ctrl0.send(wire::encode_cmd(&cmd)).expect("Ssa S0");

    // Uploads. The scrambled run sends each lane's range in reverse vid
    // order and every frame twice — duplicates must be ignored.
    let batches: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(vid, (sel, dl))| {
            let mut rng = Rng::new(0x5EED ^ vid as u64);
            ssa::client_update(session, sel, dl, &mut rng).expect("client update")
        })
        .collect();
    let send_range = |s1: &TcpTransport, s0: &TcpTransport, lo: u32, hi: u32| {
        let ids: Vec<u32> = if scrambled {
            (lo..hi).rev().collect()
        } else {
            (lo..hi).collect()
        };
        let reps = if scrambled { 2 } else { 1 };
        for _ in 0..reps {
            for &vid in &ids {
                let b = &batches[vid as usize];
                let short = lane_frame(vid, msg::encode_key_upload(b, 1, false));
                s1.send(short).expect("short upload");
                let long = lane_frame(vid, msg::encode_key_upload(b, 0, true));
                s0.send(long).expect("long upload");
            }
        }
    };
    send_range(&lane1_a, &lane0_a, 0, half);
    send_range(&lane1_b, &lane0_b, half, n_wire);

    let (delta0, out0) = round_reply(&ctrl0, "S0");
    let (delta1, out1) = round_reply(&ctrl1, "S1");
    assert!(out0.iter().all(|o| *o == ClientOutcome::Completed), "S0 outcomes: {out0:?}");
    assert!(out1.iter().all(|o| *o == ClientOutcome::Completed), "S1 outcomes: {out1:?}");
    assert!(delta1.is_none(), "only the leader reconstructs");
    let delta = delta0.expect("S0 must carry the reconstructed delta");

    let stop = wire::encode_cmd(&ServerCmd::<u64>::Shutdown);
    ctrl1.send(stop.clone()).expect("Shutdown S1");
    ctrl0.send(stop).expect("Shutdown S0");
    drop((lane0_a, lane0_b, lane1_a, lane1_b, ctrl0, ctrl1));
    h0.join().expect("S0 thread").expect("S0 serve");
    h1.join().expect("S1 thread").expect("S1 serve");
    delta
}

#[test]
fn scrambled_dials_duplicates_and_strays_match_the_sequential_delta() {
    let session = session(512, 16, 0xFEED);
    let inputs = churn_inputs(&session, 24);
    let sequential = drive_mux_round(&session, &inputs, false);
    let scrambled = drive_mux_round(&session, &inputs, true);
    assert_eq!(sequential, scrambled, "churn must not change the aggregate");

    let mut expected = vec![0u64; 512];
    for (sel, dl) in &inputs {
        for (&x, &d) in sel.iter().zip(dl) {
            expected[x as usize] = expected[x as usize].wrapping_add(d);
        }
    }
    assert_eq!(sequential, expected, "the delta must be the cohort's exact sparse sum");
}

#[test]
fn loadgen_round_trip_matches_the_in_process_runtime() {
    let (addr0, h0) = spawn_server(0);
    let (addr1, h1) = spawn_server(1);
    let mut opts = LoadgenOptions::new(addr0, addr1);
    opts.clients = 200;
    opts.lanes = 8;
    opts.m = 1024;
    opts.k = 16;
    opts.deadline = Duration::from_secs(20);
    opts.verify = LoadgenVerify::Inproc;
    let report = run_loadgen(&opts).expect("loadgen round");
    assert_eq!(report.clients, 200);
    assert_eq!(report.completed, 200, "a fault-free cohort completes fully");
    assert_eq!(report.straggler_cut, 0);
    assert_eq!(report.dropped, 0);
    assert_eq!(report.sent, 200);
    assert!(report.verified, "the delta must match the in-process replay bit-for-bit");
    h0.join().expect("S0 thread").expect("S0 serve");
    h1.join().expect("S1 thread").expect("S1 serve");
}

#[test]
fn a_straggler_cohort_cannot_extend_the_round_past_its_deadline() {
    let (addr0, h0) = spawn_server(0);
    let (addr1, h1) = spawn_server(1);
    let mut opts = LoadgenOptions::new(addr0, addr1);
    opts.clients = 400;
    opts.lanes = 8;
    opts.m = 512;
    opts.k = 16;
    opts.straggle = 0.25;
    opts.deadline = Duration::from_millis(1500);
    let report = run_loadgen(&opts).expect("straggler round");
    assert!(report.straggler_cut > 0, "a quarter of the cohort must be cut");
    assert!(report.completed > 0, "the prompt clients must commit");
    assert_eq!(report.dropped, 0, "silent clients are cut, not dropped — their lanes stay open");
    assert_eq!(report.completed + report.straggler_cut, 400);
    assert!(report.verified, "the surviving cohort's delta must verify");
    assert!(
        report.wall_time < Duration::from_secs(12),
        "the round must end near the deadline, not wait out stragglers ({:?})",
        report.wall_time
    );
    h0.join().expect("S0 thread").expect("S0 serve");
    h1.join().expect("S1 thread").expect("S1 serve");
}

#[test]
fn severed_lanes_classify_their_unsent_tail_as_dropped() {
    let (addr0, h0) = spawn_server(0);
    let (addr1, h1) = spawn_server(1);
    let mut opts = LoadgenOptions::new(addr0, addr1);
    opts.clients = 120;
    opts.lanes = 6;
    opts.m = 512;
    opts.k = 16;
    opts.drop_lanes = 2;
    opts.deadline = Duration::from_secs(4);
    let report = run_loadgen(&opts).expect("dropout round");
    assert!(report.dropped > 0, "severed lanes must drop their tails");
    assert!(report.completed > 0, "the heads and the surviving lanes must commit");
    assert!(report.sent < 120, "the injected disconnect truncates its lanes' sends");
    assert_eq!(report.completed + report.straggler_cut + report.dropped, 120);
    assert!(report.verified, "the committed head's contribution must verify");
    h0.join().expect("S0 thread").expect("S0 serve");
    h1.join().expect("S1 thread").expect("S1 serve");
}
