//! Failure injection: malicious clients, malformed messages, silent
//! parties.

use fsl::crypto::field::Fp;
use fsl::crypto::rng::Rng;
use fsl::dpf::{full_eval, gen};
use fsl::net;
use fsl::protocol::msg;
use fsl::sketch;
use std::time::Duration;

#[test]
fn sketch_rejects_double_vote() {
    // Malicious client sums two DPF key pairs (votes twice in one bin):
    // the servers' sketching check must reject w.h.p.
    let mut rng = Rng::new(700);
    let depth = 7;
    let theta = 100;
    let mut v0 = vec![Fp::zero(); theta];
    let mut v1 = vec![Fp::zero(); theta];
    for alpha in [3u64, 77] {
        let (k0, k1) = gen::<Fp>(depth, alpha, &Fp::one(), rng.gen_seed(), rng.gen_seed());
        for (acc, v) in v0.iter_mut().zip(full_eval(&k0, theta)) {
            *acc = Fp::add(*acc, v);
        }
        for (acc, v) in v1.iter_mut().zip(full_eval(&k1, theta)) {
            *acc = Fp::add(*acc, v);
        }
    }
    let r = sketch::sample_coins(&mut rng, theta);
    let mut mul = sketch::SecureMul::new(701);
    assert!(!sketch::verify_unknown_beta(&mut mul, &v0, &v1, &r));
}

#[test]
fn sketch_accepts_every_honest_bin_of_a_real_query() {
    // End-to-end: sketch every bin of an honest client's SSA upload.
    use fsl::hashing::CuckooParams;
    use fsl::protocol::{ssa, Session, SessionParams};
    let session = Session::new_full(SessionParams {
        m: 1 << 10,
        k: 16,
        cuckoo: CuckooParams::default(),
    });
    let mut rng = Rng::new(702);
    let sel = rng.sample_distinct(16, 1 << 10);
    let dl: Vec<Fp> = sel.iter().map(|&x| Fp::new(x + 1)).collect();
    let batch = ssa::client_update(&session, &sel, &dl, &mut rng).unwrap();
    let keys0 = batch.server_keys(0);
    let keys1 = batch.server_keys(1);
    let mut mul = sketch::SecureMul::new(703);
    for (j, (k0, k1)) in keys0.iter().zip(&keys1).enumerate() {
        let theta = session.simple.bin(j).len().max(1);
        let v0 = full_eval(k0, theta);
        let v1 = full_eval(k1, theta);
        let r = sketch::sample_coins(&mut rng, theta);
        assert!(
            sketch::verify_unknown_beta(&mut mul, &v0, &v1, &r),
            "honest bin {j} rejected"
        );
    }
}

#[test]
fn malformed_uploads_are_rejected_not_crashing() {
    // Every decoder must return None on garbage, never panic.
    let mut rng = Rng::new(704);
    for len in [0usize, 1, 4, 17, 100] {
        let garbage: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = msg::decode_key_upload::<u64>(&garbage);
        let _ = msg::decode_shares::<u128>(&garbage);
        let _ = msg::decode_indices(&garbage);
    }
    // Truncations of a valid message.
    use fsl::dpf::{gen_batch_with_master, BinPoint};
    let bins: Vec<BinPoint<u64>> = vec![BinPoint { depth: 9, point: Some((3, 5)) }];
    let batch = gen_batch_with_master(&bins, [1; 16], [2; 16]);
    let valid = msg::encode_key_upload(&batch, 0, true);
    for cut in [1, 10, 20, valid.len() - 1] {
        assert!(
            msg::decode_key_upload::<u64>(&valid[..cut]).is_none(),
            "truncation at {cut} accepted"
        );
    }
}

#[test]
fn silent_server_times_out() {
    let (a, _b) = net::pair(Duration::ZERO);
    let t0 = std::time::Instant::now();
    let res = a.recv_timeout(Duration::from_millis(50));
    assert!(res.is_err());
    assert!(t0.elapsed() >= Duration::from_millis(45));
}

#[test]
fn dropped_channel_is_an_error_not_a_hang() {
    let (a, b) = net::pair(Duration::ZERO);
    drop(b);
    assert!(a.send(vec![1, 2, 3]).is_err());
    assert!(a.recv().is_err());
}

#[test]
fn wrong_beta_claim_rejected() {
    // A client claiming β=1 in PSR but embedding β=2 is caught by the
    // public-β sketch (vote manipulation, §2.2 malicious-client model).
    let mut rng = Rng::new(705);
    let (k0, k1) = gen::<Fp>(6, 9, &Fp::new(2), rng.gen_seed(), rng.gen_seed());
    let v0 = full_eval(&k0, 64);
    let v1 = full_eval(&k1, 64);
    let r = sketch::sample_coins(&mut rng, 64);
    let s0 = sketch::sketch_share(&v0, &r);
    let s1 = sketch::sketch_share(&v1, &r);
    let mut mul = sketch::SecureMul::new(706);
    assert!(!sketch::verify(&mut mul, s0, s1, Fp::one()));
    // With the true β it verifies — the key itself is well-formed.
    let mut mul2 = sketch::SecureMul::new(707);
    assert!(sketch::verify(&mut mul2, s0, s1, Fp::new(2)));
}
