//! Failure injection: malicious clients, malformed messages, silent
//! parties — and the dropout-tolerance matrix: {psr, ssa, udpf-ssa} ×
//! {in-proc, tcp} × {0, 1, 25%} dropped clients, with the surviving
//! cohort's result bit-identical to a survivors-only strict baseline.

use fsl::coordinator::{
    serve, ClientOutcome, FslRuntime, FslRuntimeBuilder, KeyMode, ServeOptions,
};
use fsl::crypto::field::Fp;
use fsl::crypto::rng::Rng;
use fsl::dpf::{full_eval, gen};
use fsl::hashing::CuckooParams;
use fsl::net;
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions};
use fsl::net::transport::{FaultPlan, TransportError};
use fsl::protocol::msg;
use fsl::protocol::{Session, SessionParams};
use fsl::sketch;
use std::time::Duration;

#[test]
fn sketch_rejects_double_vote() {
    // Malicious client sums two DPF key pairs (votes twice in one bin):
    // the servers' sketching check must reject w.h.p.
    let mut rng = Rng::new(700);
    let depth = 7;
    let theta = 100;
    let mut v0 = vec![Fp::zero(); theta];
    let mut v1 = vec![Fp::zero(); theta];
    for alpha in [3u64, 77] {
        let (k0, k1) = gen::<Fp>(depth, alpha, &Fp::one(), rng.gen_seed(), rng.gen_seed());
        for (acc, v) in v0.iter_mut().zip(full_eval(&k0, theta)) {
            *acc = Fp::add(*acc, v);
        }
        for (acc, v) in v1.iter_mut().zip(full_eval(&k1, theta)) {
            *acc = Fp::add(*acc, v);
        }
    }
    let r = sketch::sample_coins(&mut rng, theta);
    let mut mul = sketch::SecureMul::new(701);
    assert!(!sketch::verify_unknown_beta(&mut mul, &v0, &v1, &r));
}

#[test]
fn sketch_accepts_every_honest_bin_of_a_real_query() {
    // End-to-end: sketch every bin of an honest client's SSA upload.
    use fsl::protocol::ssa;
    let session = Session::new_full(SessionParams {
        m: 1 << 10,
        k: 16,
        cuckoo: CuckooParams::default(),
    });
    let mut rng = Rng::new(702);
    let sel = rng.sample_distinct(16, 1 << 10);
    let dl: Vec<Fp> = sel.iter().map(|&x| Fp::new(x + 1)).collect();
    let batch = ssa::client_update(&session, &sel, &dl, &mut rng).unwrap();
    let keys0 = batch.server_keys(0);
    let keys1 = batch.server_keys(1);
    let mut mul = sketch::SecureMul::new(703);
    for (j, (k0, k1)) in keys0.iter().zip(&keys1).enumerate() {
        let theta = session.simple.bin(j).len().max(1);
        let v0 = full_eval(k0, theta);
        let v1 = full_eval(k1, theta);
        let r = sketch::sample_coins(&mut rng, theta);
        assert!(
            sketch::verify_unknown_beta(&mut mul, &v0, &v1, &r),
            "honest bin {j} rejected"
        );
    }
}

#[test]
fn malformed_uploads_are_rejected_not_crashing() {
    // Every decoder must return None on garbage, never panic.
    let mut rng = Rng::new(704);
    for len in [0usize, 1, 4, 17, 100] {
        let garbage: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = msg::decode_key_upload::<u64>(&garbage);
        let _ = msg::decode_shares::<u128>(&garbage);
        let _ = msg::decode_indices(&garbage);
    }
    // Truncations of a valid message.
    use fsl::dpf::{gen_batch_with_master, BinPoint};
    let bins: Vec<BinPoint<u64>> = vec![BinPoint { depth: 9, point: Some((3, 5)) }];
    let batch = gen_batch_with_master(&bins, [1; 16], [2; 16]);
    let valid = msg::encode_key_upload(&batch, 0, true);
    for cut in [1, 10, 20, valid.len() - 1] {
        assert!(
            msg::decode_key_upload::<u64>(&valid[..cut]).is_none(),
            "truncation at {cut} accepted"
        );
    }
}

#[test]
fn silent_server_times_out() {
    let (a, _b) = net::pair(Duration::ZERO);
    let t0 = std::time::Instant::now();
    let err = a.recv_timeout(Duration::from_millis(50)).unwrap_err();
    assert!(TransportError::is_timeout(&err), "not typed Timeout: {err:?}");
    assert!(t0.elapsed() >= Duration::from_millis(45));
}

#[test]
fn dropped_channel_is_an_error_not_a_hang() {
    let (a, b) = net::pair(Duration::ZERO);
    drop(b);
    let err = a.send(vec![1, 2, 3]).unwrap_err();
    assert!(TransportError::is_closed(&err), "not typed Closed: {err:?}");
    let err = a.recv().unwrap_err();
    assert!(TransportError::is_closed(&err), "not typed Closed: {err:?}");
}

#[test]
fn wrong_beta_claim_rejected() {
    // A client claiming β=1 in PSR but embedding β=2 is caught by the
    // public-β sketch (vote manipulation, §2.2 malicious-client model).
    let mut rng = Rng::new(705);
    let (k0, k1) = gen::<Fp>(6, 9, &Fp::new(2), rng.gen_seed(), rng.gen_seed());
    let v0 = full_eval(&k0, 64);
    let v1 = full_eval(&k1, 64);
    let r = sketch::sample_coins(&mut rng, 64);
    let s0 = sketch::sketch_share(&v0, &r);
    let s1 = sketch::sketch_share(&v1, &r);
    let mut mul = sketch::SecureMul::new(706);
    assert!(!sketch::verify(&mut mul, s0, s1, Fp::one()));
    // With the true β it verifies — the key itself is well-formed.
    let mut mul2 = sketch::SecureMul::new(707);
    assert!(sketch::verify(&mut mul2, s0, s1, Fp::new(2)));
}

// ---- dropout-tolerance matrix ------------------------------------------
//
// {psr, ssa, udpf-ssa} × {in-proc, tcp} × {0, 1, 25%} dropped clients.
// A dropped client disconnects on its very first upload; the round must
// still complete, classify every client with a typed outcome, and give
// the surviving cohort a result bit-identical to a survivors-only strict
// baseline (DPF reconstruction is exact, so the comparison is `==`, not
// approximate).

const N: usize = 8;
const M: u64 = 1 << 10;
const K: usize = 16;

/// Drop sets for the matrix: none, one, a quarter of the cohort.
const DROP_SETS: [&[usize]; 3] = [&[], &[3], &[1, 5]];

fn matrix_session() -> Session {
    Session::new_full(SessionParams {
        m: M,
        k: K,
        cuckoo: CuckooParams::default().with_seed(42),
    })
}

/// Deterministic client updates: selections are fixed across epochs (the
/// U-DPF contract) while deltas vary per epoch, so hint rounds aggregate
/// fresh values.
fn matrix_clients(epoch: u64) -> Vec<(Vec<u64>, Vec<u64>)> {
    let mut rng = Rng::new(808);
    (0..N)
        .map(|_| {
            let sel = rng.sample_distinct(K, M);
            let dl: Vec<u64> = sel.iter().map(|&x| x + 1 + epoch).collect();
            (sel, dl)
        })
        .collect()
}

fn expected_outcome(i: usize, drops: &[usize]) -> ClientOutcome {
    if drops.contains(&i) {
        ClientOutcome::Dropped
    } else {
        ClientOutcome::Completed
    }
}

/// The survivors' update sum, computed directly from the plaintext.
fn survivor_sum(clients: &[(Vec<u64>, Vec<u64>)], drops: &[usize]) -> Vec<u64> {
    let mut expected = vec![0u64; M as usize];
    for (i, (sel, dl)) in clients.iter().enumerate() {
        if drops.contains(&i) {
            continue;
        }
        for (&x, &d) in sel.iter().zip(dl) {
            expected[x as usize] = expected[x as usize].wrapping_add(d);
        }
    }
    expected
}

enum Net {
    InProc,
    Tcp,
}

type ServerHandle = std::thread::JoinHandle<anyhow::Result<()>>;

fn spawn_tcp_server(party: u8) -> (String, ServerHandle) {
    let acceptor = TcpAcceptor::bind("127.0.0.1:0", TcpOptions::default()).unwrap();
    let addr = acceptor.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let mut opts = ServeOptions::new(party);
        opts.threads = 1;
        serve::<u64>(&acceptor, &opts)
    });
    (addr, handle)
}

/// A tolerant deployment over either net, with each dropped client rigged
/// to sever its links on the very first upload message.
fn tolerant_runtime(
    net: &Net,
    drops: &[usize],
    key_mode: KeyMode,
    servers: &mut Vec<ServerHandle>,
) -> FslRuntime<u64> {
    let mut b = FslRuntimeBuilder::from_session(matrix_session())
        .threads(1)
        .max_clients(N)
        .key_mode(key_mode)
        .reply_timeout(Duration::from_secs(120))
        .upload_deadline(Duration::from_secs(5));
    for &i in drops {
        b = b.client_fault(i, FaultPlan::new().disconnect_after_messages(0));
    }
    match net {
        Net::InProc => b.build().unwrap(),
        Net::Tcp => {
            let (a0, h0) = spawn_tcp_server(0);
            let (a1, h1) = spawn_tcp_server(1);
            servers.push(h0);
            servers.push(h1);
            b.connect(&a0, &a1).unwrap()
        }
    }
}

fn ssa_matrix(net: Net, key_mode: KeyMode) {
    let epochs: u64 = match key_mode {
        KeyMode::Udpf => 2, // exercise both the setup and a hint round
        KeyMode::Fresh => 1,
    };
    for drops in DROP_SETS {
        let mut servers = Vec::new();
        let mut rt = tolerant_runtime(&net, drops, key_mode, &mut servers);
        // Strict survivors-only baseline: same session, no faults, no
        // deadline, only the clients that will survive the tolerant run.
        let mut base = FslRuntimeBuilder::from_session(matrix_session())
            .threads(1)
            .max_clients(N)
            .key_mode(key_mode)
            .build::<u64>()
            .unwrap();
        let mut rng = Rng::new(1_000);
        let mut base_rng = Rng::new(2_000);
        for epoch in 0..epochs {
            let clients = matrix_clients(epoch);
            let survivors: Vec<(Vec<u64>, Vec<u64>)> = clients
                .iter()
                .enumerate()
                .filter(|(i, _)| !drops.contains(i))
                .map(|(_, c)| c.clone())
                .collect();
            let out = rt.ssa(&clients, &mut rng).unwrap();
            let base_out = base.ssa(&survivors, &mut base_rng).unwrap();
            for (i, o) in out.report.outcomes.iter().enumerate() {
                assert_eq!(
                    *o,
                    expected_outcome(i, drops),
                    "client {i}, epoch {epoch}, drops {drops:?}"
                );
            }
            assert_eq!(
                out.delta, base_out.delta,
                "not bit-identical to the survivors-only baseline \
                 (epoch {epoch}, drops {drops:?})"
            );
            assert_eq!(
                out.delta,
                survivor_sum(&clients, drops),
                "wrong aggregate (epoch {epoch}, drops {drops:?})"
            );
        }
        rt.shutdown().unwrap();
        base.shutdown().unwrap();
        for h in servers {
            h.join().unwrap().unwrap();
        }
    }
}

fn psr_matrix(net: Net) {
    for drops in DROP_SETS {
        let mut servers = Vec::new();
        let mut rt = tolerant_runtime(&net, drops, KeyMode::Fresh, &mut servers);
        let weights: Vec<u64> = (0..M).map(|x| x.wrapping_mul(31).wrapping_add(7)).collect();
        rt.set_weights(weights.clone()).unwrap();
        let clients: Vec<Vec<u64>> = matrix_clients(0).into_iter().map(|(s, _)| s).collect();
        let out = rt.psr(&clients, &mut Rng::new(3_000)).unwrap();
        for (i, o) in out.report.outcomes.iter().enumerate() {
            assert_eq!(*o, expected_outcome(i, drops), "client {i}, drops {drops:?}");
        }
        for (i, sel) in clients.iter().enumerate() {
            let want: Vec<u64> = if drops.contains(&i) {
                Vec::new() // a dropped client retrieves nothing
            } else {
                sel.iter().map(|&x| weights[x as usize]).collect()
            };
            assert_eq!(out.submodels[i], want, "client {i}, drops {drops:?}");
        }
        rt.shutdown().unwrap();
        for h in servers {
            h.join().unwrap().unwrap();
        }
    }
}

#[test]
fn psr_tolerates_dropouts_in_proc() {
    psr_matrix(Net::InProc);
}

#[test]
fn psr_tolerates_dropouts_over_tcp() {
    psr_matrix(Net::Tcp);
}

#[test]
fn ssa_tolerates_dropouts_in_proc() {
    ssa_matrix(Net::InProc, KeyMode::Fresh);
}

#[test]
fn ssa_tolerates_dropouts_over_tcp() {
    ssa_matrix(Net::Tcp, KeyMode::Fresh);
}

#[test]
fn udpf_ssa_tolerates_dropouts_in_proc() {
    ssa_matrix(Net::InProc, KeyMode::Udpf);
}

#[test]
fn udpf_ssa_tolerates_dropouts_over_tcp() {
    ssa_matrix(Net::Tcp, KeyMode::Udpf);
}

#[test]
fn stragglers_are_cut_at_the_deadline_and_evicted_for_good() {
    // A muted client keeps "uploading" into the void: the servers see
    // silence, wait out the deadline, and cut it as a straggler.
    let mut rt = FslRuntimeBuilder::from_session(matrix_session())
        .threads(1)
        .max_clients(N)
        .upload_deadline(Duration::from_millis(400))
        .client_fault(2, FaultPlan::new().mute_after(0))
        .build::<u64>()
        .unwrap();
    let mut rng = Rng::new(4_000);
    let clients = matrix_clients(0);
    let out = rt.ssa(&clients, &mut rng).unwrap();
    assert_eq!(out.report.outcomes[2], ClientOutcome::StragglerCut);
    assert_eq!(out.report.completed(), N - 1);
    assert_eq!(out.delta, survivor_sum(&clients, &[2]));
    // Eviction is permanent: the next round reports the client Dropped
    // without waiting out another deadline, and keeps excluding it.
    let clients = matrix_clients(1);
    let out = rt.ssa(&clients, &mut rng).unwrap();
    assert_eq!(out.report.outcomes[2], ClientOutcome::Dropped);
    assert_eq!(out.report.completed(), N - 1);
    assert_eq!(out.delta, survivor_sum(&clients, &[2]));
    rt.shutdown().unwrap();
}

#[test]
fn a_slow_client_inside_the_deadline_still_completes() {
    // Added latency short of the deadline is not a fault: every client
    // completes and the aggregate includes all of them.
    let mut rt = FslRuntimeBuilder::from_session(matrix_session())
        .threads(1)
        .max_clients(N)
        .upload_deadline(Duration::from_secs(10))
        .client_fault(4, FaultPlan::new().delay(Duration::from_millis(50)))
        .build::<u64>()
        .unwrap();
    let clients = matrix_clients(0);
    let out = rt.ssa(&clients, &mut Rng::new(5_000)).unwrap();
    assert!(out
        .report
        .outcomes
        .iter()
        .all(|o| *o == ClientOutcome::Completed));
    assert_eq!(out.delta, survivor_sum(&clients, &[]));
    rt.shutdown().unwrap();
}
