//! Loopback TCP transport integration: S0 and S1 as real server threads
//! behind real sockets on ephemeral ports, driven by
//! `FslRuntimeBuilder::connect` — asserting that every round type
//! produces results bit-identical to the in-process transport, that
//! shutdown is clean, and that a wedged peer times out instead of
//! hanging the driver.

use fsl::coordinator::{serve, FslRuntimeBuilder, KeyMode, ServeOptions};
use fsl::crypto::rng::Rng;
use fsl::hashing::CuckooParams;
use fsl::net::transport::tcp::{TcpAcceptor, TcpOptions};
use fsl::net::transport::{HelloAck, Listener};
use fsl::protocol::{Session, SessionParams};
use std::net::TcpListener;
use std::thread::JoinHandle;
use std::time::Duration;

fn session(m: u64, k: usize, seed: u64) -> Session {
    Session::new_full(SessionParams {
        m,
        k,
        cuckoo: CuckooParams::default().with_seed(seed),
    })
}

/// Spawn one standalone server on an ephemeral loopback port, exactly as
/// `fsl serve` would run it (serial engine for determinism of timings).
fn spawn_server(party: u8) -> (String, JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || {
        let acceptor = TcpAcceptor::new(listener, TcpOptions::default());
        let mut opts = ServeOptions::new(party);
        opts.threads = 1;
        serve::<u64>(&acceptor, &opts)
    });
    (addr, handle)
}

fn client_updates(s: &Session, n: usize, rng: &mut Rng) -> Vec<(Vec<u64>, Vec<u64>)> {
    let (m, k) = (s.params.m, s.params.k);
    (0..n)
        .map(|c| {
            let sel = rng.sample_distinct(k, m);
            let dl = sel.iter().map(|&x| x * 7 + c as u64 + 1).collect();
            (sel, dl)
        })
        .collect()
}

#[test]
fn psr_and_ssa_over_tcp_match_in_process_bit_for_bit() {
    let s = session(2048, 32, 0xBEEF);
    let n = 3;
    let weights: Vec<u64> = {
        let mut rng = Rng::new(41);
        (0..2048).map(|_| rng.next_u64()).collect()
    };

    // In-process reference: identical rng streams drive both transports.
    let mut rng = Rng::new(42);
    let mut rt = FslRuntimeBuilder::from_session(s.clone())
        .threads(1)
        .max_clients(n)
        .build::<u64>()
        .expect("in-proc build");
    rt.set_weights(weights.clone()).unwrap();
    let sels: Vec<Vec<u64>> = (0..n).map(|_| rng.sample_distinct(32, 2048)).collect();
    let psr_ref = rt.psr(&sels, &mut rng).expect("in-proc psr");
    let updates = client_updates(&s, n, &mut rng);
    let ssa_ref = rt.ssa(&updates, &mut rng).expect("in-proc ssa");
    rt.shutdown().expect("in-proc shutdown");

    // TCP deployment: two real server threads on ephemeral ports.
    let (addr0, h0) = spawn_server(0);
    let (addr1, h1) = spawn_server(1);
    let mut rng = Rng::new(42);
    let mut rt = FslRuntimeBuilder::from_session(s.clone())
        .max_clients(n)
        .connect::<u64>(&addr0, &addr1)
        .expect("tcp connect");
    rt.set_weights(weights.clone()).unwrap();
    let sels_tcp: Vec<Vec<u64>> = (0..n).map(|_| rng.sample_distinct(32, 2048)).collect();
    assert_eq!(sels, sels_tcp, "identical rng streams must draw identically");
    let psr_tcp = rt.psr(&sels_tcp, &mut rng).expect("tcp psr");
    let updates_tcp = client_updates(&s, n, &mut rng);
    let ssa_tcp = rt.ssa(&updates_tcp, &mut rng).expect("tcp ssa");

    // Bit-identical results across transports.
    assert_eq!(psr_ref.submodels, psr_tcp.submodels, "PSR must not depend on the transport");
    assert_eq!(ssa_ref.delta, ssa_tcp.delta, "SSA must not depend on the transport");
    for (sel, got) in sels.iter().zip(&psr_tcp.submodels) {
        for (i, &x) in sel.iter().enumerate() {
            assert_eq!(got[i], weights[x as usize]);
        }
    }

    // Metering is honest per transport: TCP carries the same payloads
    // plus a 7-byte frame header per message, so its client bytes are
    // strictly larger but within the per-message overhead bound.
    assert!(
        psr_tcp.report.client_upload_bytes > psr_ref.report.client_upload_bytes,
        "TCP wire bytes include framing"
    );
    assert!(ssa_tcp.report.server_exchange_bytes > 0, "S0<->S1 bytes surface remotely");

    // Clean shutdown: both server processes (threads here) exit Ok.
    rt.shutdown().expect("tcp shutdown");
    h0.join().expect("S0 thread").expect("S0 serve Ok");
    h1.join().expect("S1 thread").expect("S1 serve Ok");
}

#[test]
fn udpf_epochs_over_tcp_match_in_process() {
    let s = session(1024, 16, 0xD00D);
    let n = 2;
    let epochs = 3;

    let run = |build: &dyn Fn() -> fsl::coordinator::FslRuntime<u64>| -> Vec<Vec<u64>> {
        let mut rng = Rng::new(77);
        let mut rt = build();
        // The U-DPF contract: fixed client set and selections per epoch.
        let updates = client_updates(&s, n, &mut rng);
        let mut deltas = Vec::new();
        for _ in 0..epochs {
            deltas.push(rt.ssa(&updates, &mut rng).expect("udpf round").delta);
        }
        rt.shutdown().expect("shutdown");
        deltas
    };

    let reference = run(&|| {
        FslRuntimeBuilder::from_session(s.clone())
            .threads(1)
            .max_clients(n)
            .key_mode(KeyMode::Udpf)
            .build::<u64>()
            .expect("in-proc build")
    });

    let (addr0, h0) = spawn_server(0);
    let (addr1, h1) = spawn_server(1);
    let over_tcp = run(&|| {
        FslRuntimeBuilder::from_session(s.clone())
            .max_clients(n)
            .key_mode(KeyMode::Udpf)
            .connect::<u64>(&addr0, &addr1)
            .expect("tcp connect")
    });

    assert_eq!(reference, over_tcp, "U-DPF setup + hint epochs must match over TCP");
    h0.join().unwrap().expect("S0 serve Ok");
    h1.join().unwrap().expect("S1 serve Ok");
}

#[test]
fn psu_alignment_over_tcp_matches_in_process() {
    let s = session(4096, 24, 0xA11E);
    let n = 3;
    let key = [9u8; 16];

    let run = |build: &dyn Fn() -> fsl::coordinator::FslRuntime<u64>| {
        let mut rng = Rng::new(55);
        let mut rt = build();
        let sets: Vec<Vec<u64>> =
            (0..n).map(|_| rng.sample_distinct(24, 4096)).collect();
        let psu = rt.psu_align(&key, &sets, &mut rng).expect("psu round");
        let theta = rt.session().theta();
        // One SSA round on the shrunken union domain.
        let updates: Vec<(Vec<u64>, Vec<u64>)> = sets
            .iter()
            .map(|sel| (sel.clone(), sel.iter().map(|&x| x + 3).collect()))
            .collect();
        let delta = rt.ssa(&updates, &mut rng).expect("post-psu ssa").delta;
        rt.shutdown().expect("shutdown");
        (psu.union_len, theta, delta)
    };

    let reference = run(&|| {
        FslRuntimeBuilder::from_session(s.clone())
            .threads(1)
            .max_clients(n)
            .build::<u64>()
            .expect("in-proc build")
    });

    let (addr0, h0) = spawn_server(0);
    let (addr1, h1) = spawn_server(1);
    let over_tcp = run(&|| {
        FslRuntimeBuilder::from_session(s.clone())
            .max_clients(n)
            .connect::<u64>(&addr0, &addr1)
            .expect("tcp connect")
    });

    assert_eq!(reference, over_tcp, "PSU union install must match over TCP");
    h0.join().unwrap().expect("S0 serve Ok");
    h1.join().unwrap().expect("S1 serve Ok");
}

#[test]
fn wedged_peer_times_out_instead_of_hanging() {
    // A fake S1 that completes every handshake and then goes silent: the
    // driver's connect must fail within its reply timeout — not hang —
    // with an error naming the silent server.
    let n = 2;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr1 = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let acceptor = TcpAcceptor::new(listener, TcpOptions::default());
        let mut keep_alive = Vec::new();
        // Ack the control conn and every client link, then wedge.
        for _ in 0..(1 + n) {
            if let Ok((conn, _hello)) = acceptor.accept() {
                let _ = conn.send(HelloAck { party: 1, error: None }.encode());
                keep_alive.push(conn);
            }
        }
        std::thread::sleep(Duration::from_secs(20));
        drop(keep_alive);
    });
    // Real S0 (its serve thread parks on the never-dialled peer accept;
    // intentionally not joined).
    let (addr0, _h0) = spawn_server(0);

    let t0 = std::time::Instant::now();
    let err = FslRuntimeBuilder::from_session(session(512, 8, 1))
        .max_clients(n)
        .reply_timeout(Duration::from_millis(400))
        .connect::<u64>(&addr0, &addr1)
        .map(|_| ())
        .unwrap_err();
    let rendered = format!("{err:?}");
    assert!(rendered.contains("S1"), "error should name the silent server: {rendered}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "a wedged peer must time out promptly, took {:?}",
        t0.elapsed()
    );
}

#[test]
fn payload_group_mismatch_is_rejected_at_the_handshake() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr0 = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let acceptor = TcpAcceptor::new(listener, TcpOptions::default());
        let mut opts = ServeOptions::new(0);
        opts.threads = 1;
        // A u64 server; the driver below speaks u128. (Never completes a
        // deployment — intentionally not joined.)
        let _ = serve::<u64>(&acceptor, &opts);
    });
    let err = FslRuntimeBuilder::from_session(session(512, 8, 2))
        .connect_timeout(Duration::from_secs(5))
        .connect::<u128>(&addr0, "127.0.0.1:1") // S1 never reached
        .map(|_| ())
        .unwrap_err();
    let rendered = format!("{err:?}");
    assert!(
        rendered.contains("group mismatch"),
        "the handshake should explain the group mismatch: {rendered}"
    );
}
