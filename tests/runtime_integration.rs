//! Integration tests over the artifact runtime.
//!
//! They run against whatever backend `Executor::new("artifacts")`
//! resolves: the manifest written by `make artifacts` when present, or
//! the built-in manifest + reference executor on a clean checkout (no
//! Python step required). Verified here: shapes, metadata, numeric
//! agreement with the rust-side ring arithmetic, and gradient sanity.

use fsl::crypto::rng::Rng;
use fsl::runtime::Executor;

fn executor() -> Executor {
    Executor::new("artifacts").expect("artifact manifest unreadable")
}

#[test]
fn manifest_lists_all_artifacts() {
    let exec = executor();
    for name in ["mlp_grad", "embbag_grad", "mlp_infer", "embbag_infer", "binned_ip"] {
        assert!(
            exec.manifest().entries.contains_key(name),
            "missing artifact {name}"
        );
        // HLO text only exists on disk when `make artifacts` produced the
        // manifest; the built-in manifest needs no files.
        if !exec.manifest().builtin {
            assert!(exec.manifest().hlo_path(name).unwrap().exists());
        }
    }
    assert_eq!(exec.manifest().int("mlp_grad", "params").unwrap(), 1_863_690);
    assert_eq!(exec.manifest().int("embbag_grad", "params").unwrap(), 150_214);
}

#[test]
fn binned_ip_matches_rust_ring_arithmetic() {
    // The L1 Pallas kernel (via HLO) must be bit-identical to the rust u64
    // wrapping inner product — this is the cross-language contract the PSR
    // server path relies on.
    let exec = executor();
    let (bins, theta) = exec.binned_ip_shape().unwrap();
    let mut rng = Rng::new(160);
    let w: Vec<u64> = (0..bins * theta).map(|_| rng.next_u64()).collect();
    let s: Vec<u64> = (0..bins * theta).map(|_| rng.next_u64()).collect();
    let got = exec.binned_ip(&w, &s).unwrap();
    assert_eq!(got.len(), bins);
    for j in 0..bins {
        let mut want = 0u64;
        for d in 0..theta {
            want = want.wrapping_add(w[j * theta + d].wrapping_mul(s[j * theta + d]));
        }
        assert_eq!(got[j], want, "bin {j}");
    }
}

#[test]
fn mlp_train_step_gradient_descends() {
    let exec = executor();
    let m = exec.manifest().int("mlp_grad", "params").unwrap() as usize;
    let batch = exec.manifest().int("mlp_grad", "batch").unwrap() as usize;
    let mut rng = Rng::new(161);
    let params: Vec<f32> = (0..m).map(|_| rng.gen_normal() as f32 * 0.02).collect();
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.gen_f64() as f32).collect();
    let mut y = vec![0f32; batch * 10];
    for r in 0..batch {
        y[r * 10 + r % 10] = 1.0;
    }
    let s0 = exec.train_step("mlp_grad", &params, &x, &y).unwrap();
    assert!(s0.loss.is_finite() && s0.loss > 0.0);
    assert_eq!(s0.grad.len(), m);
    // One SGD step must reduce the loss on the same batch.
    let stepped: Vec<f32> = params
        .iter()
        .zip(&s0.grad)
        .map(|(p, g)| p - 0.1 * g)
        .collect();
    let s1 = exec.train_step("mlp_grad", &stepped, &x, &y).unwrap();
    assert!(s1.loss < s0.loss, "{} !< {}", s1.loss, s0.loss);
}

#[test]
fn infer_matches_grad_loss_direction() {
    // Softmax CE consistency: training on a single repeated batch drives
    // the infer logits toward the labels.
    let exec = executor();
    let m = exec.manifest().int("embbag_grad", "params").unwrap() as usize;
    let batch = exec.manifest().int("embbag_grad", "batch").unwrap() as usize;
    let vocab = exec.manifest().int("embbag_grad", "vocab").unwrap() as usize;
    let mut rng = Rng::new(162);
    let mut params: Vec<f32> = (0..m).map(|_| rng.gen_normal() as f32 * 0.05).collect();
    let mut bow = vec![0f32; batch * vocab];
    let mut y = vec![0f32; batch * 6];
    for r in 0..batch {
        let class = r % 6;
        for w in 0..8 {
            bow[r * vocab + class * 100 + w] = 1.0;
        }
        y[r * 6 + class] = 1.0;
    }
    for _ in 0..10 {
        let st = exec.train_step("embbag_grad", &params, &bow, &y).unwrap();
        for (p, g) in params.iter_mut().zip(&st.grad) {
            *p -= 0.5 * g;
        }
    }
    let logits = exec.infer("embbag_infer", &params, &bow).unwrap();
    let mut correct = 0;
    for r in 0..batch {
        let rl = &logits[r * 6..(r + 1) * 6];
        let pred = rl
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        correct += usize::from(pred == r % 6);
    }
    assert!(correct * 2 > batch, "only {correct}/{batch} learned");
}

#[test]
fn executor_rejects_bad_shapes() {
    let exec = executor();
    let err = exec.train_step("mlp_grad", &[0.0; 10], &[0.0; 10], &[0.0; 10]);
    assert!(err.is_err());
    let err = exec.binned_ip(&[1u64; 3], &[1u64; 3]);
    assert!(err.is_err());
    assert!(exec.infer("nonexistent", &[], &[]).is_err());
}
