//! Vendored, dependency-free subset of the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so the
//! workspace ships the small slice of `anyhow` the `fsl` crate actually
//! uses as a path dependency: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the [`anyhow!`] / [`bail!`] / [`ensure!`] macros.
//!
//! Semantics match upstream where it matters:
//! * `Error` does **not** implement `std::error::Error`, which is what
//!   makes the blanket `From<E: std::error::Error>` conversion coherent
//!   (the same trick upstream uses).
//! * `?` therefore works on any std error type, and on `Error` itself via
//!   the reflexive `From`.
//! * `Display` prints the outermost message; `Debug` (what `unwrap` and
//!   `main` print) shows the whole cause chain.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        items.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the std cause chain into ours so Debug output keeps it.
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut cur: Option<&(dyn StdError + 'static)> = e.source();
        while let Some(c) = cur {
            chain.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.expect("non-empty chain")
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring upstream `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error case.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error case.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/here").context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_on_std_errors() {
        let err = io_fail().unwrap_err();
        assert_eq!(err.to_string(), "reading config");
        assert!(err.chain().count() >= 2);
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert_eq!(check(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(check(5).unwrap_err().to_string(), "five is right out");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(
            v.with_context(|| "missing").unwrap_err().to_string(),
            "missing"
        );
    }

    #[test]
    fn debug_shows_chain() {
        let err = io_fail().unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("reading config"));
        assert!(dbg.contains("Caused by:"));
    }
}
