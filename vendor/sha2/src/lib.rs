//! Vendored, dependency-free subset of the `sha2` crate: SHA-256 with the
//! `Digest` streaming API (`new` / `update` / `finalize`).
//!
//! The build environment has no network access to crates.io, so this
//! path crate stands in for the real `sha2` crate. `finalize` returns a
//! plain `[u8; 32]` rather than a `GenericArray`; every call site in
//! `fsl` only slices the digest, so the two are interchangeable. The
//! implementation is pinned to the FIPS-180 test vectors below.

/// Streaming-hash interface (subset of the `digest` crate's trait).
pub trait Digest {
    /// The finalized digest type.
    type Output;
    /// Fresh hasher in the initial state.
    fn new() -> Self;
    /// Absorb input bytes.
    fn update(&mut self, data: impl AsRef<[u8]>);
    /// Consume the hasher and produce the digest.
    fn finalize(self) -> Self::Output;
}

/// Round constants: fractional parts of the cube roots of the first 64
/// primes (FIPS-180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: fractional parts of the square roots of the first
/// 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// SHA-256 hasher.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partially filled input block.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes.
    total: u64,
}

impl Sha256 {
    fn compress(state: &mut [u32; 8], block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, wi) in w.iter_mut().take(16).enumerate() {
            *wi = u32::from_be_bytes(block[4 * i..4 * i + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
        for i in 0..64 {
            let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(big_s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = big_s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

impl Digest for Sha256 {
    type Output = [u8; 32];

    fn new() -> Self {
        Sha256 {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total: 0,
        }
    }

    fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total += data.len() as u64;
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                Self::compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            Self::compress(&mut self.state, data[..64].try_into().unwrap());
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total * 8;
        self.update([0x80u8]);
        while self.buf_len != 56 {
            self.update([0u8]);
        }
        // Length block bytes go straight into the buffer (update would
        // also grow `total`, but `bit_len` is already captured).
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        Self::compress(&mut self.state, &block);
        let mut out = [0u8; 32];
        for (chunk, s) in out.chunks_exact_mut(4).zip(self.state) {
            chunk.copy_from_slice(&s.to_be_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(digest: &[u8]) -> String {
        digest.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn sha(data: &[u8]) -> String {
        let mut h = Sha256::new();
        h.update(data);
        hex(&h.finalize())
    }

    #[test]
    fn fips_vectors() {
        assert_eq!(
            sha(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..997u32).map(|i| i as u8).collect();
        let mut h = Sha256::new();
        for chunk in data.chunks(13) {
            h.update(chunk);
        }
        let mut one = Sha256::new();
        one.update(&data);
        assert_eq!(h.finalize(), one.finalize());
    }

    #[test]
    fn million_a() {
        // FIPS-180 third vector: 1,000,000 repetitions of 'a'.
        let mut h = Sha256::new();
        for _ in 0..1000 {
            h.update([b'a'; 1000]);
        }
        assert_eq!(
            hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }
}
