//! Vendored, dependency-free subset of the `aes` crate: AES-128
//! encryption only, with the `Block` / `cipher::{KeyInit, BlockEncrypt}`
//! API surface the `fsl` PRG uses.
//!
//! The build environment has no network access to crates.io, so this
//! path crate stands in for the real `aes` crate. It is a portable
//! table-based (T-table) software implementation — no AES-NI intrinsics —
//! whose S-box and round tables are *derived* at first use from the
//! GF(2^8) field definition rather than transcribed, and whose output is
//! pinned to the FIPS-197 test vectors below.
//!
//! Security note: a table-based software AES is not constant-time. For
//! this repository that is acceptable — AES is used as a *PRG* on secret
//! seeds inside a research simulation, not as an encryption service
//! exposed to co-located attackers. Swapping in the real `aes` crate
//! (hardware AES-NI, constant-time) requires no source changes in `fsl`.

use std::ops::{Deref, DerefMut};
use std::sync::OnceLock;

/// One 16-byte AES block.
///
/// Mirrors the `aes` crate's `Block` (a `GenericArray<u8, U16>`): derefs
/// to `[u8; 16]`, is `Copy`, and converts into a plain byte array.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Block([u8; 16]);

impl Block {
    /// Copy a 16-byte slice into a fresh block.
    ///
    /// # Panics
    /// Panics if `slice.len() != 16` (same contract as `GenericArray`).
    pub fn clone_from_slice(slice: &[u8]) -> Self {
        let mut b = [0u8; 16];
        b.copy_from_slice(slice);
        Block(b)
    }
}

impl Deref for Block {
    type Target = [u8; 16];
    fn deref(&self) -> &[u8; 16] {
        &self.0
    }
}

impl DerefMut for Block {
    fn deref_mut(&mut self) -> &mut [u8; 16] {
        &mut self.0
    }
}

impl From<Block> for [u8; 16] {
    fn from(b: Block) -> [u8; 16] {
        b.0
    }
}

impl From<[u8; 16]> for Block {
    fn from(b: [u8; 16]) -> Block {
        Block(b)
    }
}

/// Cipher construction / usage traits (subset of the `cipher` crate).
pub mod cipher {
    use std::fmt;

    /// Error returned when a key slice has the wrong length.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct InvalidLength;

    impl fmt::Display for InvalidLength {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("invalid key length")
        }
    }

    impl std::error::Error for InvalidLength {}

    /// Construct a cipher from key material.
    pub trait KeyInit: Sized {
        /// Build from a key slice; errors if the length is wrong.
        fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength>;
    }

    /// Block-encryption operations.
    pub trait BlockEncrypt {
        /// Encrypt one block in place.
        fn encrypt_block(&self, block: &mut super::Block);

        /// Encrypt a run of blocks in place.
        fn encrypt_blocks(&self, blocks: &mut [super::Block]) {
            for b in blocks {
                self.encrypt_block(b);
            }
        }
    }
}

// ------------------------- table construction ---------------------------

/// GF(2^8) doubling with the AES reduction polynomial x^8+x^4+x^3+x+1.
#[inline]
const fn xtime(a: u8) -> u8 {
    if a & 0x80 != 0 {
        (a << 1) ^ 0x1b
    } else {
        a << 1
    }
}

/// GF(2^8) multiplication (shift-and-add).
const fn gmul(mut a: u8, mut b: u8) -> u8 {
    let mut p = 0u8;
    let mut i = 0;
    while i < 8 {
        if b & 1 != 0 {
            p ^= a;
        }
        a = xtime(a);
        b >>= 1;
        i += 1;
    }
    p
}

/// GF(2^8) inverse via a^254 (a^255 = 1 for a ≠ 0; inv(0) := 0).
const fn ginv(a: u8) -> u8 {
    if a == 0 {
        return 0;
    }
    let mut r = 1u8;
    let mut base = a;
    let mut e = 254u32;
    while e > 0 {
        if e & 1 != 0 {
            r = gmul(r, base);
        }
        base = gmul(base, base);
        e >>= 1;
    }
    r
}

/// The AES S-box, derived from the field definition (inversion followed
/// by the FIPS-197 affine transform) instead of transcribed.
const fn build_sbox() -> [u8; 256] {
    let mut sbox = [0u8; 256];
    let mut x = 0usize;
    while x < 256 {
        let i = ginv(x as u8);
        sbox[x] = i
            ^ i.rotate_left(1)
            ^ i.rotate_left(2)
            ^ i.rotate_left(3)
            ^ i.rotate_left(4)
            ^ 0x63;
        x += 1;
    }
    sbox
}

const SBOX: [u8; 256] = build_sbox();

/// Four round tables combining SubBytes + ShiftRows + MixColumns.
/// `TE[0][x] = (2·S[x], S[x], S[x], 3·S[x])` packed big-endian; the other
/// three are byte rotations of the first.
fn tables() -> &'static [[u32; 256]; 4] {
    static TABLES: OnceLock<[[u32; 256]; 4]> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut te = [[0u32; 256]; 4];
        for x in 0..256 {
            let s = SBOX[x];
            let t0 = (gmul(2, s) as u32) << 24
                | (s as u32) << 16
                | (s as u32) << 8
                | gmul(3, s) as u32;
            te[0][x] = t0;
            te[1][x] = t0.rotate_right(8);
            te[2][x] = t0.rotate_right(16);
            te[3][x] = t0.rotate_right(24);
        }
        te
    })
}

// ------------------------------ AES-128 ---------------------------------

/// AES-128 block cipher (encryption only — the PRG and CTR constructions
/// in `fsl` never decrypt).
#[derive(Clone)]
pub struct Aes128 {
    /// 11 round keys × 4 big-endian words.
    round_keys: [u32; 44],
}

impl Aes128 {
    fn expand_key(key: &[u8; 16]) -> [u32; 44] {
        let mut w = [0u32; 44];
        for (i, wi) in w.iter_mut().take(4).enumerate() {
            *wi = u32::from_be_bytes([
                key[4 * i],
                key[4 * i + 1],
                key[4 * i + 2],
                key[4 * i + 3],
            ]);
        }
        let mut rcon: u8 = 1;
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                // RotWord then SubWord then Rcon.
                t = t.rotate_left(8);
                t = (SBOX[(t >> 24) as usize] as u32) << 24
                    | (SBOX[(t >> 16) as usize & 0xff] as u32) << 16
                    | (SBOX[(t >> 8) as usize & 0xff] as u32) << 8
                    | SBOX[t as usize & 0xff] as u32;
                t ^= (rcon as u32) << 24;
                rcon = xtime(rcon);
            }
            w[i] = w[i - 4] ^ t;
        }
        w
    }
}

impl cipher::KeyInit for Aes128 {
    fn new_from_slice(key: &[u8]) -> Result<Self, cipher::InvalidLength> {
        let key: &[u8; 16] = key.try_into().map_err(|_| cipher::InvalidLength)?;
        Ok(Aes128 {
            round_keys: Self::expand_key(key),
        })
    }
}

impl cipher::BlockEncrypt for Aes128 {
    fn encrypt_block(&self, block: &mut Block) {
        let te = tables();
        let w = &self.round_keys;
        let b = &block.0;
        let mut s0 = u32::from_be_bytes([b[0], b[1], b[2], b[3]]) ^ w[0];
        let mut s1 = u32::from_be_bytes([b[4], b[5], b[6], b[7]]) ^ w[1];
        let mut s2 = u32::from_be_bytes([b[8], b[9], b[10], b[11]]) ^ w[2];
        let mut s3 = u32::from_be_bytes([b[12], b[13], b[14], b[15]]) ^ w[3];
        for round in 1..10 {
            let rk = &w[round * 4..round * 4 + 4];
            let t0 = te[0][(s0 >> 24) as usize]
                ^ te[1][(s1 >> 16) as usize & 0xff]
                ^ te[2][(s2 >> 8) as usize & 0xff]
                ^ te[3][s3 as usize & 0xff]
                ^ rk[0];
            let t1 = te[0][(s1 >> 24) as usize]
                ^ te[1][(s2 >> 16) as usize & 0xff]
                ^ te[2][(s3 >> 8) as usize & 0xff]
                ^ te[3][s0 as usize & 0xff]
                ^ rk[1];
            let t2 = te[0][(s2 >> 24) as usize]
                ^ te[1][(s3 >> 16) as usize & 0xff]
                ^ te[2][(s0 >> 8) as usize & 0xff]
                ^ te[3][s1 as usize & 0xff]
                ^ rk[2];
            let t3 = te[0][(s3 >> 24) as usize]
                ^ te[1][(s0 >> 16) as usize & 0xff]
                ^ te[2][(s1 >> 8) as usize & 0xff]
                ^ te[3][s2 as usize & 0xff]
                ^ rk[3];
            s0 = t0;
            s1 = t1;
            s2 = t2;
            s3 = t3;
        }
        // Final round: SubBytes + ShiftRows + AddRoundKey (no MixColumns).
        let rk = &w[40..44];
        let sub = |a: u32, b: u32, c: u32, d: u32, k: u32| -> u32 {
            ((SBOX[(a >> 24) as usize] as u32) << 24
                | (SBOX[(b >> 16) as usize & 0xff] as u32) << 16
                | (SBOX[(c >> 8) as usize & 0xff] as u32) << 8
                | SBOX[d as usize & 0xff] as u32)
                ^ k
        };
        let o0 = sub(s0, s1, s2, s3, rk[0]);
        let o1 = sub(s1, s2, s3, s0, rk[1]);
        let o2 = sub(s2, s3, s0, s1, rk[2]);
        let o3 = sub(s3, s0, s1, s2, rk[3]);
        block.0[0..4].copy_from_slice(&o0.to_be_bytes());
        block.0[4..8].copy_from_slice(&o1.to_be_bytes());
        block.0[8..12].copy_from_slice(&o2.to_be_bytes());
        block.0[12..16].copy_from_slice(&o3.to_be_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::cipher::{BlockEncrypt, KeyInit};
    use super::*;

    fn hex(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    #[test]
    fn sbox_known_entries() {
        assert_eq!(SBOX[0x00], 0x63);
        assert_eq!(SBOX[0x01], 0x7c);
        assert_eq!(SBOX[0x53], 0xed);
        assert_eq!(SBOX[0xff], 0x16);
    }

    #[test]
    fn fips197_appendix_c1() {
        // Key 000102…0f, plaintext 00112233…eeff.
        let key: Vec<u8> = (0..16).collect();
        let cipher = Aes128::new_from_slice(&key).unwrap();
        let mut b = Block::clone_from_slice(&hex("00112233445566778899aabbccddeeff"));
        cipher.encrypt_block(&mut b);
        assert_eq!(&b[..], &hex("69c4e0d86a7b0430d8cdb78070b4c55a")[..]);
    }

    #[test]
    fn fips197_appendix_b() {
        let cipher = Aes128::new_from_slice(&hex("2b7e151628aed2a6abf7158809cf4f3c")).unwrap();
        let mut b = Block::clone_from_slice(&hex("3243f6a8885a308d313198a2e0370734"));
        cipher.encrypt_block(&mut b);
        assert_eq!(&b[..], &hex("3925841d02dc09fbdc118597196a0b32")[..]);
    }

    #[test]
    fn key_schedule_first_expanded_word() {
        // FIPS-197 Appendix A: w4 = a0fafe17 for the Appendix-B key.
        let k = hex("2b7e151628aed2a6abf7158809cf4f3c");
        let w = Aes128::expand_key(k.as_slice().try_into().unwrap());
        assert_eq!(w[4], 0xa0fafe17);
        assert_eq!(w[43], 0xb6630ca6);
    }

    #[test]
    fn blocks_batch_matches_single() {
        let cipher = Aes128::new_from_slice(&[7u8; 16]).unwrap();
        let mut batch: Vec<Block> = (0..67u8)
            .map(|i| Block::clone_from_slice(&[i; 16]))
            .collect();
        let mut singles = batch.clone();
        cipher.encrypt_blocks(&mut batch);
        for b in &mut singles {
            cipher.encrypt_block(b);
        }
        assert_eq!(batch, singles);
    }

    #[test]
    fn wrong_key_length_rejected() {
        assert!(Aes128::new_from_slice(&[0u8; 15]).is_err());
        assert!(Aes128::new_from_slice(&[0u8; 32]).is_err());
    }
}
