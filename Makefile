# Convenience targets. The rust side needs none of these: a clean
# checkout builds and tests with `cargo build --release && cargo test -q`
# (the runtime falls back to its built-in manifest + reference backend).

.PHONY: artifacts test bench doc fmt lint clean

# AOT-lower the L2/L1 graphs to HLO text + manifest.json (needs jax).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

test:
	cargo test -q

bench:
	cargo bench

doc:
	cargo doc --no-deps

fmt:
	cargo fmt --all --check

# The repo-invariant lint pass (panic-freedom, secret hygiene, decode
# bounds, determinism, deprecated API use) — see docs/ARCHITECTURE.md
# "Invariants & static analysis".
lint:
	cargo run -p xtask -- lint

clean:
	cargo clean
	rm -rf artifacts
