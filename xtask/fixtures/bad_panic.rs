//! Rule-1 fixture: a bare `.unwrap()` on the server path.

pub fn first_byte(v: &[u8]) -> u8 {
    *v.first().unwrap()
}
