//! Rule-4 fixture: wall-clock reads inside the deterministic core.

pub fn elapsed_nanos() -> u128 {
    std::time::Instant::now().elapsed().as_nanos()
}
