//! Rule-2 fixture: a SECRET_TYPES manifest type deriving Debug.

#[derive(Clone, Debug)]
pub struct DpfKey {
    pub root_seed: [u8; 16],
}
