//! Rule-1 fixture: an escape hatch with no justification is itself a
//! violation — the marker alone does not buy a panic.

pub fn first_byte(v: &[u8]) -> u8 {
    // lint: allow(panic)
    *v.first().unwrap()
}
