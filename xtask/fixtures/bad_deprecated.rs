//! Rule-5 fixture: deprecated API use outside a labelled equivalence
//! test, with no justification marker.

#[allow(deprecated)]
pub fn calls_legacy_api() {}
