//! Lint fixture: metric registration literals that violate the naming
//! convention (`fsl_[a-z0-9_]+` plus a `_bytes|_total|_seconds|_count`
//! unit suffix), alongside one compliant name and one justified legacy
//! escape hatch.

pub fn register(reg: &Registry) -> Handles {
    Handles {
        // Wrong prefix: every family is namespaced under `fsl_`.
        frames: reg.counter("frames_total", "frames moved through the pump"),
        // No unit suffix: a reader cannot tell bytes from counts.
        held: reg.gauge("fsl_held_window", "bytes parked in the commit window"),
        // Uppercase breaks the `fsl_[a-z0-9_]+` shape.
        rounds: reg.histogram("fsl_Round_seconds", "round wall time", Unit::Seconds),
        // lint: allow(metric-naming) — grandfathered dashboard family, renamed when the collector migrates
        legacy: reg.counter("legacy_frames", "pre-convention family"),
        ok: reg.counter("fsl_frames_total", "frames moved through the pump"),
    }
}
