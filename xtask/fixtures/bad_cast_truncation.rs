//! Rule-6 fixture: a bare narrowing cast in a wire-scoped file. The
//! bad count wraps at 2^32 and writes a corrupt frame; the clamped
//! variant below carries a justification marker and must pass.

pub fn encode_count(out: &mut Vec<u8>, n: usize) {
    out.extend_from_slice(&(n as u32).to_be_bytes());
}

pub fn encode_count_clamped(out: &mut Vec<u8>, n: usize) {
    // lint: allow(cast-truncation) — n is clamped to u32::MAX on the same expression.
    out.extend_from_slice(&(n.min(u32::MAX as usize) as u32).to_be_bytes());
}
