//! The happy-path fixture: exercises every rule's accepted form —
//! cap-before-allocation decoding, a justified escape hatch, and panics
//! confined to `#[cfg(test)]` items.

pub const MAX_WIRE_ITEMS: usize = 1 << 10;

pub fn decode_items(bytes: &[u8]) -> Option<Vec<u8>> {
    let count = *bytes.first()? as usize;
    if count > MAX_WIRE_ITEMS {
        return None;
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(0);
    }
    Some(out)
}

pub fn register_metrics(reg: &Registry) -> Counter {
    // Convention-clean: `fsl_` prefix, lowercase body, unit suffix.
    reg.counter("fsl_clean_frames_total", "frames moved by the fixture")
}

pub fn checked_head(v: &[u8]) -> u8 {
    // lint: allow(panic) — fixture demonstrating a justified escape hatch.
    v.first().copied().expect("fixture invariant: non-empty input")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_inside_tests_is_fine() {
        let v = decode_items(&[1, 0]).unwrap();
        assert_eq!(*v.first().unwrap(), 0);
    }
}
