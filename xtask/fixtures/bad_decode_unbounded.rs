//! Rule-3 fixture: a decoder that allocates from a wire-declared count
//! before checking any MAX_WIRE_* cap.

pub fn decode_things(bytes: &[u8]) -> Option<Vec<u8>> {
    let count = bytes.first().copied()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(0);
    }
    Some(out)
}
