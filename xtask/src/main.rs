//! fsl-lint: the repo's invariant static-analysis pass, plus the
//! `bench-diff` trajectory gate over `artifacts/HISTORY.jsonl`.
//!
//! Run as `cargo run -p xtask -- lint` (or `make lint`). Seven rules
//! over `rust/src/**`, enforced token-wise on comment/string-stripped
//! source with `#[cfg(test)]` items excised:
//!
//! 1. **panic** — no `.unwrap()` / `.expect(` / `panic!(` /
//!    `unreachable!(` in `protocol/`, `net/`, or the server-path
//!    coordinator modules (`serve`, `wire`, `runtime`, `snapshot`).
//!    Server code must fail with typed errors, never a process abort.
//! 2. **secret-debug** — no type in [`SECRET_TYPES`] may derive or
//!    implement `Debug`/`Display`; key material must not be formattable.
//! 3. **decode-bounds** — every `decode_*`/`read_*` in the two wire
//!    codecs checks a `MAX_WIRE_*`/`MAX_FRAME_*` cap before its first
//!    length-driven allocation, so a hostile frame costs an error, not
//!    gigabytes.
//! 4. **determinism** — no `Instant::now` / `SystemTime` / `rand::` in
//!    `dpf/`, `crypto/`, `protocol/`: the cryptographic core must be a
//!    pure function of its inputs (reproducible transcripts, seedable
//!    tests).
//! 5. **deprecated** — no `#[allow(deprecated)]` outside test items;
//!    legacy APIs live on only inside labelled equivalence tests.
//! 6. **cast-truncation** — no bare `as u32`/`as u16`/`as u8` in the
//!    [`CAST_TRUNCATION_FILES`] (the runtime and its wire codec): a
//!    count that silently wraps on encode corrupts the frame three
//!    layers away. Use `try_from` (or the codec's clamped `put_count`)
//!    and justify the rare intentional narrowing with an allow marker.
//! 7. **metric-naming** — every literal name handed to a
//!    `MetricsRegistry` registration call (`.counter(` / `.gauge(` /
//!    `.histogram(` and their `_with` forms) must match
//!    `fsl_[a-z0-9_]+` and end in a unit suffix
//!    (`_bytes`/`_total`/`_seconds`/`_count`), so scrape families stay
//!    greppable and unit-honest across the whole tree.
//!
//! Escape hatch: a `// lint: allow(<rule>) — <justification>` comment on
//! the flagged line or within the [`ALLOW_WINDOW`] lines above it
//! suppresses that rule there. The justification is mandatory — a bare
//! marker is itself a violation.

use std::fmt;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

mod bench_diff;

/// Types that carry DPF key material (root/master/leaf seeds). Nothing in
/// this manifest may derive or implement `Debug`/`Display`; their seed
/// fields are wrapped in `crypto::Sensitive`, which redacts itself.
const SECRET_TYPES: &[&str] = &[
    "DpfKey",
    "MasterKeyBatch",
    "BinPoint",
    "UdpfKey",
    "UdpfClientState",
];

/// How many lines above a flagged token an allow marker still covers
/// (markers usually sit above a rustfmt-wrapped call chain).
const ALLOW_WINDOW: usize = 8;

/// Coordinator files held to the panic-freedom rule (the modules a
/// standalone server actually runs; the legacy single-process drivers are
/// exempt).
const PANIC_FREE_COORDINATOR: &[&str] = &[
    "coordinator/serve.rs",
    "coordinator/wire.rs",
    "coordinator/runtime.rs",
    "coordinator/snapshot.rs",
];

/// The wire codecs whose decoders must cap before allocating.
const DECODE_BOUND_FILES: &[&str] = &["protocol/msg.rs", "coordinator/wire.rs"];

/// Files where a silently-wrapping numeric narrowing has corrupted (or
/// would corrupt) wire frames: counts must go through `try_from` or the
/// codec's clamped `put_count`, never a bare `as` cast.
const CAST_TRUNCATION_FILES: &[&str] = &["coordinator/wire.rs", "coordinator/runtime.rs"];

/// Registration-call tokens whose first argument is a metric name. The
/// `_with` forms are separate tokens because `.counter(` requires the
/// opening paren immediately after the method name.
const METRIC_REGISTRATION_TOKENS: &[&str] = &[
    ".counter(",
    ".counter_with(",
    ".gauge(",
    ".gauge_with(",
    ".histogram(",
    ".histogram_with(",
];

/// Every registered metric name must end with one of these, so a scrape
/// reader can tell a byte meter from a latency histogram by name alone.
const METRIC_UNIT_SUFFIXES: &[&str] = &["_bytes", "_total", "_seconds", "_count"];

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// A parsed `// lint: allow(<rule>) — <justification>` marker.
struct Allow {
    rule: String,
    justified: bool,
}

/// Per-file preprocessed views. All three texts are byte-for-byte the
/// same length as the source (stripped regions become spaces, newlines
/// survive), so byte offsets map straight to source lines.
struct Pre {
    /// Comments and string/char literals blanked.
    stripped: String,
    /// `stripped` with every `#[cfg(test)]` item additionally blanked.
    excised: String,
    /// Byte offset of the start of each line.
    line_starts: Vec<usize>,
    /// Allow marker (if any) per 0-based line.
    allows: Vec<Option<Allow>>,
}

impl Pre {
    fn new(src: &str) -> Pre {
        let stripped = strip_comments_and_literals(src);
        let excised = excise_test_items(&stripped);
        let mut line_starts = vec![0usize];
        for (i, b) in src.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(i + 1);
            }
        }
        Pre {
            stripped,
            excised,
            line_starts,
            allows: parse_allows(src),
        }
    }
}

// ---- text preprocessing ------------------------------------------------

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn prev_ident(b: &[u8], i: usize) -> bool {
    i > 0 && is_ident(b[i - 1])
}

fn blank(out: &mut Vec<u8>, b: &[u8], from: usize, to: usize) {
    for &byte in &b[from..to.min(b.len())] {
        out.push(if byte == b'\n' { b'\n' } else { b' ' });
    }
}

/// `r"…"`, `r#"…"#`, `br"…"` openers.
fn is_raw_start(b: &[u8], i: usize) -> bool {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return false;
    }
    j += 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

/// End (exclusive) of the raw string starting at `i`.
fn raw_string_end(b: &[u8], i: usize) -> usize {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    j += 1; // the `r`
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    j += 1; // the opening quote
    while j < b.len() {
        if b[j] == b'"' {
            let mut k = 0usize;
            while k < hashes && b.get(j + 1 + k) == Some(&b'#') {
                k += 1;
            }
            if k == hashes {
                return j + 1 + hashes;
            }
        }
        j += 1;
    }
    b.len()
}

/// If a char/byte literal starts at the quote `b[i]`, its end
/// (exclusive); `None` means the quote is a lifetime.
fn char_lit_end(b: &[u8], i: usize) -> Option<usize> {
    let n = *b.get(i + 1)?;
    if n == b'\\' {
        // Escape: the escaped char is at i+2, so the closing quote is at
        // i+3 at the earliest ('\u{…}' runs longer; cap the scan).
        let limit = (i + 14).min(b.len());
        (i + 3..limit).find(|&j| b[j] == b'\'').map(|j| j + 1)
    } else if n == b'\'' {
        None
    } else if b.get(i + 2) == Some(&b'\'') {
        Some(i + 3)
    } else if n >= 0x80 {
        // Multibyte scalar like 'é': closing quote within a few bytes.
        let limit = (i + 7).min(b.len());
        (i + 2..limit).find(|&j| b[j] == b'\'').map(|j| j + 1)
    } else {
        None
    }
}

/// Replace comments, string/char literals, and raw strings with spaces,
/// preserving newlines (and therefore every byte offset and line number).
fn strip_comments_and_literals(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let mut j = i;
            while j < b.len() && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, b, i, j);
            i = j;
        } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1i32;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'/' && b.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && b.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let j = j.min(b.len());
            blank(&mut out, b, i, j);
            i = j;
        } else if c == b'"' {
            let mut j = i + 1;
            while j < b.len() {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let j = j.min(b.len());
            blank(&mut out, b, i, j);
            i = j;
        } else if (c == b'r' || c == b'b') && !prev_ident(b, i) && is_raw_start(b, i) {
            let j = raw_string_end(b, i);
            blank(&mut out, b, i, j);
            i = j;
        } else if c == b'b' && b.get(i + 1) == Some(&b'\'') {
            match char_lit_end(b, i + 1) {
                Some(j) => {
                    blank(&mut out, b, i, j);
                    i = j;
                }
                None => {
                    out.push(c);
                    i += 1;
                }
            }
        } else if c == b'\'' {
            match char_lit_end(b, i) {
                Some(j) => {
                    blank(&mut out, b, i, j);
                    i = j;
                }
                None => {
                    out.push(c);
                    i += 1;
                }
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    String::from_utf8(out).unwrap_or_default()
}

/// Blank every item decorated with `#[cfg(test)]` (attribute through the
/// item's closing brace or semicolon). Operates on stripped text, so
/// braces inside literals cannot confuse the matcher.
fn excise_test_items(stripped: &str) -> String {
    const MARKER: &[u8] = b"#[cfg(test)]";
    let mut buf = stripped.as_bytes().to_vec();
    while let Some(pos) = find_sub(&buf, MARKER, 0) {
        let mut end = buf.len();
        let mut j = pos + MARKER.len();
        while j < buf.len() {
            match buf[j] {
                b'{' => {
                    end = match_brace(&buf, j) + 1;
                    break;
                }
                b';' => {
                    end = j + 1;
                    break;
                }
                _ => j += 1,
            }
        }
        let end = end.min(buf.len());
        for byte in &mut buf[pos..end] {
            if *byte != b'\n' {
                *byte = b' ';
            }
        }
    }
    String::from_utf8(buf).unwrap_or_default()
}

/// Index of the `}` matching the `{` at `open` (or `len` if unmatched).
fn match_brace(hay: &[u8], open: usize) -> usize {
    let mut depth = 0i64;
    for (i, &b) in hay.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth <= 0 {
                    return i;
                }
            }
            _ => {}
        }
    }
    hay.len()
}

fn find_sub(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

/// 1-based line number of byte offset `pos`.
fn line_of(line_starts: &[usize], pos: usize) -> usize {
    match line_starts.binary_search(&pos) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

fn parse_allows(src: &str) -> Vec<Option<Allow>> {
    src.lines()
        .map(|line| {
            let comment = &line[line.find("//")?..];
            let at = comment.find("lint: allow(")?;
            let rest = &comment[at + "lint: allow(".len()..];
            let close = rest.find(')')?;
            let rule = rest[..close].trim().to_string();
            let just = rest[close + 1..]
                .trim_start_matches(|ch: char| {
                    ch.is_whitespace() || matches!(ch, '\u{2014}' | '\u{2013}' | '-' | ':' | ',')
                })
                .trim();
            Some(Allow {
                rule,
                justified: just.chars().count() >= 8,
            })
        })
        .collect()
}

/// The covering allow marker for `rule` at 1-based `line`, if any.
fn find_allow<'a>(allows: &'a [Option<Allow>], line: usize, rule: &str) -> Option<&'a Allow> {
    let hi = line.min(allows.len());
    let lo = line.saturating_sub(ALLOW_WINDOW + 1);
    allows[lo..hi].iter().rev().flatten().find(|a| a.rule == rule)
}

fn flag(
    out: &mut Vec<Violation>,
    pre: &Pre,
    file: &str,
    line: usize,
    rule: &'static str,
    msg: String,
) {
    match find_allow(&pre.allows, line, rule) {
        Some(a) if a.justified => {}
        Some(_) => out.push(Violation {
            file: file.to_string(),
            line,
            rule,
            msg: format!("`lint: allow({rule})` marker lacks a justification — {msg}"),
        }),
        None => out.push(Violation {
            file: file.to_string(),
            line,
            rule,
            msg,
        }),
    }
}

// ---- the seven rules ---------------------------------------------------

fn rule_panic(file: &str, pre: &Pre, out: &mut Vec<Violation>) {
    let scoped = file.starts_with("protocol/")
        || file.starts_with("net/")
        || PANIC_FREE_COORDINATOR.contains(&file);
    if !scoped {
        return;
    }
    let hay = pre.excised.as_bytes();
    for (tok, boundary) in [
        (".unwrap()", false),
        (".expect(", false),
        ("panic!(", true),
        ("unreachable!(", true),
    ] {
        let mut from = 0usize;
        while let Some(pos) = find_sub(hay, tok.as_bytes(), from) {
            from = pos + 1;
            if boundary && prev_ident(hay, pos) {
                continue;
            }
            let line = line_of(&pre.line_starts, pos);
            flag(
                out,
                pre,
                file,
                line,
                "panic",
                format!(
                    "`{tok}…` in a panic-free module — return a typed error, \
                     or add `// lint: allow(panic) — <why>`"
                ),
            );
        }
    }
}

fn rule_secret(file: &str, pre: &Pre, out: &mut Vec<Violation>) {
    let hay = pre.stripped.as_bytes();
    let lines: Vec<&str> = pre.stripped.lines().collect();
    for ty in SECRET_TYPES {
        // (a) the definition must not derive Debug.
        let needle = format!("struct {ty}");
        let mut from = 0usize;
        while let Some(pos) = find_sub(hay, needle.as_bytes(), from) {
            from = pos + 1;
            let end = pos + needle.len();
            if prev_ident(hay, pos) || (end < hay.len() && is_ident(hay[end])) {
                continue;
            }
            let defn_line = line_of(&pre.line_starts, pos);
            let mut l = defn_line - 1; // 0-based index of the defn line
            let mut steps = 0usize;
            while l > 0 && steps < 15 {
                l -= 1;
                steps += 1;
                let t = lines.get(l).map(|s| s.trim()).unwrap_or("");
                if t.is_empty() {
                    continue;
                }
                if !t.starts_with("#[") {
                    break;
                }
                if t.contains("derive") && t.contains("Debug") {
                    flag(
                        out,
                        pre,
                        file,
                        l + 1,
                        "secret-debug",
                        format!("secret type `{ty}` derives Debug — key material must not be formattable"),
                    );
                }
            }
        }
        // (b) no manual Debug/Display impl either.
        for imp in ["Debug for ", "Display for "] {
            let needle = format!("{imp}{ty}");
            let mut from = 0usize;
            while let Some(pos) = find_sub(hay, needle.as_bytes(), from) {
                from = pos + 1;
                let end = pos + needle.len();
                if prev_ident(hay, pos) || (end < hay.len() && is_ident(hay[end])) {
                    continue;
                }
                let line = line_of(&pre.line_starts, pos);
                flag(
                    out,
                    pre,
                    file,
                    line,
                    "secret-debug",
                    format!("manual `{imp}{ty}` impl — key material must not be formattable"),
                );
            }
        }
    }
}

fn rule_decode_bounds(file: &str, pre: &Pre, out: &mut Vec<Violation>) {
    if !DECODE_BOUND_FILES.contains(&file) {
        return;
    }
    let hay = pre.excised.as_bytes();
    for prefix in ["fn decode_", "fn read_"] {
        let mut from = 0usize;
        while let Some(pos) = find_sub(hay, prefix.as_bytes(), from) {
            from = pos + 1;
            if prev_ident(hay, pos) {
                continue;
            }
            let name_start = pos + 3; // past "fn "
            let mut name_end = name_start;
            while name_end < hay.len() && is_ident(hay[name_end]) {
                name_end += 1;
            }
            let name = String::from_utf8_lossy(&hay[name_start..name_end]).into_owned();
            let Some(open) = find_sub(hay, b"{", pos) else {
                continue;
            };
            let close = match_brace(hay, open);
            let body = &hay[open..close];
            let alloc = [
                find_sub(body, b"with_capacity", 0),
                find_sub(body, b"vec![0", 0),
            ]
            .into_iter()
            .flatten()
            .min();
            let Some(alloc) = alloc else { continue };
            let cap = [
                find_sub(body, b"MAX_WIRE_", 0),
                find_sub(body, b"MAX_FRAME_", 0),
            ]
            .into_iter()
            .flatten()
            .min();
            if !cap.is_some_and(|c| c < alloc) {
                let line = line_of(&pre.line_starts, open + alloc);
                flag(
                    out,
                    pre,
                    file,
                    line,
                    "decode-bounds",
                    format!(
                        "`{name}` allocates from a wire-derived length before \
                         checking a MAX_WIRE_*/MAX_FRAME_* cap"
                    ),
                );
            }
        }
    }
}

fn rule_determinism(file: &str, pre: &Pre, out: &mut Vec<Violation>) {
    let scoped = file.starts_with("dpf/")
        || file.starts_with("crypto/")
        || file.starts_with("protocol/");
    if !scoped {
        return;
    }
    let hay = pre.excised.as_bytes();
    for tok in ["Instant::now", "SystemTime", "rand::"] {
        let mut from = 0usize;
        while let Some(pos) = find_sub(hay, tok.as_bytes(), from) {
            from = pos + 1;
            if prev_ident(hay, pos) {
                continue;
            }
            let line = line_of(&pre.line_starts, pos);
            flag(
                out,
                pre,
                file,
                line,
                "determinism",
                format!(
                    "`{tok}` in the deterministic core — thread clocks and \
                     entropy in from the caller instead"
                ),
            );
        }
    }
}

fn rule_deprecated(file: &str, pre: &Pre, out: &mut Vec<Violation>) {
    let hay = pre.excised.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = find_sub(hay, b"#[allow(deprecated)]", from) {
        from = pos + 1;
        let line = line_of(&pre.line_starts, pos);
        flag(
            out,
            pre,
            file,
            line,
            "deprecated",
            "deprecated API use outside a labelled equivalence test — migrate, \
             or add `// lint: allow(deprecated) — <why>`"
                .to_string(),
        );
    }
}

fn rule_cast_truncation(file: &str, pre: &Pre, out: &mut Vec<Violation>) {
    if !CAST_TRUNCATION_FILES.contains(&file) {
        return;
    }
    let hay = pre.excised.as_bytes();
    for tok in ["as u32", "as u16", "as u8"] {
        let mut from = 0usize;
        while let Some(pos) = find_sub(hay, tok.as_bytes(), from) {
            from = pos + 1;
            let end = pos + tok.len();
            if prev_ident(hay, pos) || (end < hay.len() && is_ident(hay[end])) {
                continue;
            }
            let line = line_of(&pre.line_starts, pos);
            flag(
                out,
                pre,
                file,
                line,
                "cast-truncation",
                format!(
                    "bare `{tok}` cast — a value past the target's range wraps \
                     silently and corrupts the wire frame; use `try_from` (or \
                     `put_count` for encode-side counts), or add \
                     `// lint: allow(cast-truncation) — <why it cannot truncate>`"
                ),
            );
        }
    }
}

/// Why `name` violates the metric-naming convention, if it does.
fn metric_name_error(name: &str) -> Option<String> {
    let body_ok = name
        .bytes()
        .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_');
    if !name.starts_with("fsl_") || name.len() <= "fsl_".len() || !body_ok {
        return Some(format!("metric name {name:?} must match `fsl_[a-z0-9_]+`"));
    }
    if !METRIC_UNIT_SUFFIXES.iter().any(|s| name.ends_with(s)) {
        return Some(format!(
            "metric name {name:?} lacks a unit suffix (_bytes|_total|_seconds|_count)"
        ));
    }
    None
}

/// Rule 7: registration literals must follow the naming convention. Call
/// sites are located in the excised text (so comments, strings and test
/// items cannot fake one); the literal itself is read back from the raw
/// source, which the preprocessing kept byte-aligned. Non-literal first
/// arguments are skipped — a dynamic name flows through a helper whose
/// own literal call sites are linted instead.
fn rule_metric_naming(file: &str, src: &str, pre: &Pre, out: &mut Vec<Violation>) {
    let hay = pre.excised.as_bytes();
    let raw = src.as_bytes();
    for tok in METRIC_REGISTRATION_TOKENS {
        let mut from = 0usize;
        while let Some(pos) = find_sub(hay, tok.as_bytes(), from) {
            from = pos + 1;
            let mut j = pos + tok.len();
            while j < raw.len() && raw[j].is_ascii_whitespace() {
                j += 1;
            }
            if raw.get(j) != Some(&b'"') {
                continue;
            }
            let start = j + 1;
            let mut end = start;
            while end < raw.len() && raw[end] != b'"' && raw[end] != b'\n' {
                end += 1;
            }
            let name = String::from_utf8_lossy(&raw[start..end]);
            if let Some(msg) = metric_name_error(&name) {
                let line = line_of(&pre.line_starts, pos);
                flag(
                    out,
                    pre,
                    file,
                    line,
                    "metric-naming",
                    format!("{msg} — rename it, or add `// lint: allow(metric-naming) — <why>`"),
                );
            }
        }
    }
}

// ---- driver ------------------------------------------------------------

fn lint_file(rel: &str, src: &str) -> Vec<Violation> {
    let pre = Pre::new(src);
    let mut out = Vec::new();
    rule_panic(rel, &pre, &mut out);
    rule_secret(rel, &pre, &mut out);
    rule_decode_bounds(rel, &pre, &mut out);
    rule_determinism(rel, &pre, &mut out);
    rule_deprecated(rel, &pre, &mut out);
    rule_cast_truncation(rel, &pre, &mut out);
    rule_metric_naming(rel, src, &pre, &mut out);
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn lint_tree(src_root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    let mut out = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(src_root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(f)?;
        out.extend(lint_file(&rel, &text));
    }
    Ok(out)
}

fn usage() -> ExitCode {
    eprintln!("usage: cargo run -p xtask -- lint [--root <repo>]");
    eprintln!("       cargo run -p xtask -- bench-diff [--history <path>]");
    ExitCode::from(2)
}

/// Repo root: `--root` if given, else the parent of the xtask manifest.
fn repo_root(root: Option<PathBuf>) -> PathBuf {
    root.unwrap_or_else(|| {
        std::env::var_os("CARGO_MANIFEST_DIR")
            .map(PathBuf::from)
            .and_then(|d| d.parent().map(Path::to_path_buf))
            .unwrap_or_else(|| PathBuf::from("."))
    })
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd: Option<&str> = None;
    let mut root: Option<PathBuf> = None;
    let mut history: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--history" => match it.next() {
                Some(p) => history = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "lint" if cmd.is_none() => cmd = Some("lint"),
            "bench-diff" if cmd.is_none() => cmd = Some("bench-diff"),
            _ => return usage(),
        }
    }
    if cmd == Some("bench-diff") {
        let path = history
            .unwrap_or_else(|| repo_root(root).join("artifacts").join("HISTORY.jsonl"));
        return bench_diff::run(&path);
    }
    if cmd != Some("lint") {
        return usage();
    }
    let src = repo_root(root).join("rust").join("src");
    if !src.is_dir() {
        eprintln!(
            "lint: {} is not a directory (run from the repo root or pass --root)",
            src.display()
        );
        return ExitCode::from(2);
    }
    match lint_tree(&src) {
        Err(e) => {
            eprintln!("lint: walking {}: {e}", src.display());
            ExitCode::from(2)
        }
        Ok(vs) if vs.is_empty() => {
            println!(
                "lint: rust/src clean (panic, secret-debug, decode-bounds, determinism, \
                 deprecated, cast-truncation, metric-naming)"
            );
            ExitCode::SUCCESS
        }
        Ok(vs) => {
            for v in &vs {
                eprintln!("{v}");
            }
            eprintln!("lint: {} violation(s)", vs.len());
            ExitCode::from(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn strip_blanks_comments_and_literals() {
        let src = "let a = \"panic!(x)\"; // .unwrap()\nlet c = 'x';\n";
        let s = strip_comments_and_literals(src);
        assert_eq!(s.len(), src.len());
        assert!(!s.contains("panic!"));
        assert!(!s.contains(".unwrap()"));
        assert!(s.contains("let a ="));
        assert!(s.lines().count() == src.lines().count());
    }

    #[test]
    fn strip_keeps_lifetimes_but_blanks_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { '\\n' }";
        let s = strip_comments_and_literals(src);
        assert!(s.contains("<'a>"), "lifetime survived: {s}");
        assert!(!s.contains("\\n"), "char literal blanked: {s}");
    }

    #[test]
    fn strip_handles_raw_strings() {
        let src = "let r = r#\"has .unwrap() inside\"#; let x = 1;";
        let s = strip_comments_and_literals(src);
        assert!(!s.contains(".unwrap()"));
        assert!(s.contains("let x = 1;"));
    }

    #[test]
    fn excision_blanks_test_mods() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let e = excise_test_items(&strip_comments_and_literals(src));
        assert!(e.contains("fn live()"));
        assert!(!e.contains("unwrap"));
    }

    #[test]
    fn fixture_panic_is_rejected() {
        let vs = lint_file(
            "protocol/bad_panic.rs",
            include_str!("../fixtures/bad_panic.rs"),
        );
        assert!(rules_of(&vs).contains(&"panic"), "{vs:?}");
    }

    #[test]
    fn fixture_unjustified_allow_is_rejected() {
        let vs = lint_file(
            "protocol/bad_allow.rs",
            include_str!("../fixtures/bad_allow_unjustified.rs"),
        );
        assert!(rules_of(&vs).contains(&"panic"), "{vs:?}");
        assert!(vs.iter().any(|v| v.msg.contains("justification")), "{vs:?}");
    }

    #[test]
    fn fixture_secret_debug_is_rejected() {
        let vs = lint_file(
            "dpf/bad_secret.rs",
            include_str!("../fixtures/bad_secret_debug.rs"),
        );
        assert!(rules_of(&vs).contains(&"secret-debug"), "{vs:?}");
    }

    #[test]
    fn fixture_unbounded_decode_is_rejected() {
        let vs = lint_file(
            "protocol/msg.rs",
            include_str!("../fixtures/bad_decode_unbounded.rs"),
        );
        assert!(rules_of(&vs).contains(&"decode-bounds"), "{vs:?}");
    }

    #[test]
    fn fixture_nondeterminism_is_rejected() {
        let vs = lint_file(
            "dpf/bad_time.rs",
            include_str!("../fixtures/bad_nondeterminism.rs"),
        );
        assert!(rules_of(&vs).contains(&"determinism"), "{vs:?}");
    }

    #[test]
    fn fixture_deprecated_is_rejected() {
        let vs = lint_file(
            "coordinator/bad_deprecated.rs",
            include_str!("../fixtures/bad_deprecated.rs"),
        );
        assert!(rules_of(&vs).contains(&"deprecated"), "{vs:?}");
    }

    #[test]
    fn fixture_cast_truncation_is_rejected() {
        let vs = lint_file(
            "coordinator/wire.rs",
            include_str!("../fixtures/bad_cast_truncation.rs"),
        );
        assert!(rules_of(&vs).contains(&"cast-truncation"), "{vs:?}");
        // The justified clamp in the same fixture must NOT be flagged.
        let flagged = vs.iter().filter(|v| v.rule == "cast-truncation").count();
        assert_eq!(flagged, 1, "{vs:?}");
    }

    #[test]
    fn cast_truncation_is_scoped_and_test_exempt() {
        // Out of scope: the same cast is fine elsewhere.
        let src = "fn f(n: usize) -> u32 { n as u32 }";
        assert!(lint_file("metrics/report.rs", src).is_empty());
        // In scope but inside a #[cfg(test)] item: excised, not flagged.
        let test_only = "#[cfg(test)]\nmod tests {\n    fn f(n: usize) -> u32 { n as u32 }\n}\n";
        assert!(lint_file("coordinator/runtime.rs", test_only).is_empty());
        // In scope, live code: flagged.
        assert!(rules_of(&lint_file("coordinator/runtime.rs", src)).contains(&"cast-truncation"));
    }

    #[test]
    fn fixture_bad_metric_names_are_rejected() {
        let vs = lint_file(
            "metrics/example.rs",
            include_str!("../fixtures/bad_metric_name.rs"),
        );
        let flagged: Vec<_> = vs.iter().filter(|v| v.rule == "metric-naming").collect();
        assert_eq!(flagged.len(), 3, "{vs:?}");
        assert!(
            flagged.iter().any(|v| v.msg.contains("unit suffix")),
            "{vs:?}"
        );
        // The compliant name and the justified legacy allow are silent.
        assert!(!vs.iter().any(|v| v.msg.contains("fsl_frames_total")), "{vs:?}");
    }

    #[test]
    fn metric_naming_skips_dynamic_names_and_test_items() {
        // A non-literal first argument cannot be checked here; the
        // helper's own literal call sites are linted instead.
        let dynamic = "fn f(reg: &R, name: &str) { reg.counter(name, \"h\"); }";
        assert!(lint_file("metrics/example.rs", dynamic).is_empty());
        // Registrations inside #[cfg(test)] items are excised.
        let test_only =
            "#[cfg(test)]\nmod tests {\n    fn f(reg: &R) { reg.gauge(\"nope\", \"h\"); }\n}\n";
        assert!(lint_file("metrics/example.rs", test_only).is_empty());
        // A literal in live code is held to the convention everywhere.
        let live = "fn f(reg: &R) { reg.gauge(\"nope\", \"h\"); }";
        assert!(rules_of(&lint_file("dpf/anywhere.rs", live)).contains(&"metric-naming"));
    }

    #[test]
    fn fixture_clean_passes_every_rule() {
        let vs = lint_file("protocol/clean.rs", include_str!("../fixtures/clean.rs"));
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn out_of_scope_files_may_panic() {
        let vs = lint_file("metrics/report.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }");
        assert!(vs.is_empty(), "{vs:?}");
    }

    /// The acceptance gate: the real tree is clean under all seven rules.
    #[test]
    fn repo_tree_is_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask sits inside the repo")
            .join("rust")
            .join("src");
        let vs = lint_tree(&src).expect("walk rust/src");
        let rendered: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
        assert!(vs.is_empty(), "lint violations:\n{}", rendered.join("\n"));
    }
}
