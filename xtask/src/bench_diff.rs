//! `bench-diff`: the perf-trajectory gate over `artifacts/HISTORY.jsonl`.
//!
//! Every bench run appends one schema-versioned datapoint per bench (see
//! `fsl::metrics::history`). This command groups the file by `bench`,
//! compares the newest datapoint against the one before it, and fails
//! (exit 1) when a `_ms` metric regresses by more than [`MS_TOLERANCE`]
//! (with an [`MS_FLOOR`] absolute floor so microsecond jitter on tiny
//! timings cannot trip it; quantile fields like the `loadgen_soak`
//! curve's `p50_ms`/`p95_ms`/`p99_ms` get the wider
//! [`QUANTILE_TOLERANCE`] because log2-bucketed quantiles move in whole
//! octaves) or when any `_bytes` metric grows at all —
//! wire bytes are deterministic, so any increase is a real protocol
//! regression, not noise. Benches with fewer than two datapoints are
//! skipped with a note; a missing history file is exit 2 (run the
//! benches first). Parsing is done by the self-contained JSON reader
//! below — the workspace stays dependency-free.

use std::collections::BTreeMap;
use std::path::Path;
use std::process::ExitCode;

/// Relative slowdown tolerated on `_ms` metrics before it counts as a
/// regression: new > old × (1 + 0.20).
const MS_TOLERANCE: f64 = 0.20;

/// Absolute floor (milliseconds): a `_ms` metric must also grow by more
/// than this for the relative check to trip, so a 0.3 ms → 0.5 ms blip
/// on a trivial timing does not fail CI.
const MS_FLOOR: f64 = 2.0;

/// Histogram-quantile fields ride a log2-bucket geometry: a reading sits
/// on a bucket bound, so ordinary jitter can flip it a whole octave
/// (×2) with no real regression underneath. These fields tolerate one
/// octave plus the usual noise margin before gating.
const QUANTILE_FIELDS: &[&str] = &["p50_ms", "p95_ms", "p99_ms"];

/// Relative slowdown tolerated on [`QUANTILE_FIELDS`]: new > old × 2.2
/// fails — anything past a clean octave flip.
const QUANTILE_TOLERANCE: f64 = 1.2;

// ---- minimal JSON value parser -----------------------------------------

/// The subset of JSON the history file uses. Arrays are parsed (so the
/// reader is total over JSON) but nothing in the envelope emits them.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { b: s.as_bytes(), i: 0 }
    }

    fn err(&self, what: &str) -> String {
        format!("{what} at byte {}", self.i)
    }

    fn skip_ws(&mut self) {
        while self.b.get(self.i).is_some_and(|c| c.is_ascii_whitespace()) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        self.skip_ws();
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .b
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("malformed number"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogates never appear in the envelope; map
                            // them to the replacement char rather than err.
                            out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(&c) if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                Some(_) => {
                    // Multi-byte UTF-8: copy the whole scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().ok_or_else(|| self.err("empty"))?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

/// Parse one complete JSON value (trailing whitespace allowed).
pub fn parse_json(s: &str) -> Result<Json, String> {
    let mut p = Parser::new(s);
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing garbage after JSON value"));
    }
    Ok(v)
}

// ---- the diff itself ---------------------------------------------------

/// One history line: the bench it belongs to plus its numeric metrics
/// (non-numeric metrics are ignored — only `_ms`/`_bytes` trends gate).
struct Datapoint {
    bench: String,
    git_rev: String,
    metrics: BTreeMap<String, f64>,
}

fn parse_line(line_no: usize, line: &str) -> Result<Option<Datapoint>, String> {
    let v = parse_json(line).map_err(|e| format!("line {line_no}: {e}"))?;
    let schema = v.get("schema").and_then(Json::as_f64);
    if schema != Some(1.0) {
        // Forward compatibility: a future schema is a skip, not a failure.
        eprintln!(
            "bench-diff: line {line_no}: unknown schema {schema:?}, skipping"
        );
        return Ok(None);
    }
    let bench = v
        .get("bench")
        .and_then(Json::as_str)
        .ok_or_else(|| format!("line {line_no}: missing \"bench\""))?
        .to_string();
    let git_rev = v
        .get("git_rev")
        .and_then(Json::as_str)
        .unwrap_or("unknown")
        .to_string();
    let mut metrics = BTreeMap::new();
    if let Some(Json::Obj(fields)) = v.get("metrics") {
        for (k, val) in fields {
            if let Some(n) = val.as_f64() {
                metrics.insert(k.clone(), n);
            }
        }
    }
    Ok(Some(Datapoint { bench, git_rev, metrics }))
}

/// Compare the newest datapoint against the previous one. Returns the
/// regression messages (empty = pass).
fn compare(prev: &Datapoint, new: &Datapoint) -> Vec<String> {
    let mut regressions = Vec::new();
    for (key, &new_v) in &new.metrics {
        let Some(&old_v) = prev.metrics.get(key) else {
            continue;
        };
        if key.ends_with("_ms") {
            let tolerance = if QUANTILE_FIELDS.contains(&key.as_str()) {
                QUANTILE_TOLERANCE
            } else {
                MS_TOLERANCE
            };
            let over_rel = new_v > old_v * (1.0 + tolerance);
            let over_abs = new_v - old_v > MS_FLOOR;
            if over_rel && over_abs {
                regressions.push(format!(
                    "{}: {key} regressed {old_v:.3} ms -> {new_v:.3} ms \
                     (+{:.1}%, tolerance {:.0}%) [{} -> {}]",
                    new.bench,
                    (new_v / old_v - 1.0) * 100.0,
                    tolerance * 100.0,
                    prev.git_rev,
                    new.git_rev,
                ));
            }
        } else if key.ends_with("_bytes") && new_v > old_v {
            regressions.push(format!(
                "{}: {key} grew {old_v:.0} -> {new_v:.0} bytes — wire sizes are \
                 deterministic, any growth is a protocol change [{} -> {}]",
                new.bench, prev.git_rev, new.git_rev,
            ));
        }
    }
    regressions
}

/// Diff the raw history text. Returns `Ok(regressions)` or a parse error.
fn diff_history(text: &str) -> Result<Vec<String>, String> {
    let mut by_bench: BTreeMap<String, Vec<Datapoint>> = BTreeMap::new();
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        if let Some(dp) = parse_line(idx + 1, line)? {
            by_bench.entry(dp.bench.clone()).or_default().push(dp);
        }
    }
    if by_bench.is_empty() {
        println!("bench-diff: no datapoints yet — nothing to compare");
        return Ok(Vec::new());
    }
    let mut regressions = Vec::new();
    for (bench, points) in &by_bench {
        if points.len() < 2 {
            println!(
                "bench-diff: {bench}: only {} datapoint(s), skipping (need 2)",
                points.len()
            );
            continue;
        }
        let new = &points[points.len() - 1];
        let prev = &points[points.len() - 2];
        let found = compare(prev, new);
        if found.is_empty() {
            println!(
                "bench-diff: {bench}: ok ({} metrics, {} -> {})",
                new.metrics.len(),
                prev.git_rev,
                new.git_rev
            );
        }
        regressions.extend(found);
    }
    Ok(regressions)
}

/// Entry point for `cargo run -p xtask -- bench-diff`.
pub fn run(path: &Path) -> ExitCode {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!(
                "bench-diff: cannot read {}: {e} (run the benches first — they \
                 append datapoints there)",
                path.display()
            );
            return ExitCode::from(2);
        }
    };
    match diff_history(&text) {
        Err(e) => {
            eprintln!("bench-diff: {}: {e}", path.display());
            ExitCode::from(2)
        }
        Ok(regressions) if regressions.is_empty() => {
            println!("bench-diff: no regressions");
            ExitCode::SUCCESS
        }
        Ok(regressions) => {
            for r in &regressions {
                eprintln!("bench-diff: REGRESSION: {r}");
            }
            eprintln!("bench-diff: {} regression(s)", regressions.len());
            ExitCode::from(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(bench: &str, rev: &str, metrics: &[(&str, f64)]) -> String {
        let body: Vec<String> = metrics
            .iter()
            .map(|(k, v)| format!("\"{k}\":{v}"))
            .collect();
        format!(
            "{{\"schema\":1,\"bench\":\"{bench}\",\"git_rev\":\"{rev}\",\
             \"unix_ts\":1700000000,\"metrics\":{{{}}}}}",
            body.join(",")
        )
    }

    #[test]
    fn json_parser_roundtrips_the_envelope() {
        let v = parse_json(
            "{\"schema\":1,\"bench\":\"x\",\"git_rev\":\"abc\",\"unix_ts\":2,\
             \"metrics\":{\"a_ms\":1.5,\"s\":\"e\\u00e9\\n\",\"arr\":[1,true,null]}}",
        )
        .expect("parse");
        assert_eq!(v.get("schema").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("bench").and_then(Json::as_str), Some("x"));
        let m = v.get("metrics").expect("metrics");
        assert_eq!(m.get("a_ms").and_then(Json::as_f64), Some(1.5));
        assert_eq!(m.get("s").and_then(Json::as_str), Some("eé\n"));
        assert_eq!(
            m.get("arr"),
            Some(&Json::Arr(vec![Json::Num(1.0), Json::Bool(true), Json::Null]))
        );
        assert!(parse_json("{\"a\":1} junk").is_err());
        assert!(parse_json("{\"a\":}").is_err());
    }

    #[test]
    fn injected_ms_regression_fails() {
        let hist = [
            line("psr", "aaa", &[("serial_ms", 100.0)]),
            line("psr", "bbb", &[("serial_ms", 130.0)]),
        ]
        .join("\n");
        let regs = diff_history(&hist).expect("parse");
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("serial_ms"), "{regs:?}");
    }

    #[test]
    fn within_tolerance_and_jitter_floor_pass() {
        // +19% — under the 20% relative tolerance.
        let hist = [
            line("psr", "aaa", &[("serial_ms", 100.0)]),
            line("psr", "bbb", &[("serial_ms", 119.0)]),
        ]
        .join("\n");
        assert!(diff_history(&hist).expect("parse").is_empty());
        // +100% but only +0.5 ms — under the absolute jitter floor.
        let hist = [
            line("psr", "aaa", &[("tiny_ms", 0.5)]),
            line("psr", "bbb", &[("tiny_ms", 1.0)]),
        ]
        .join("\n");
        assert!(diff_history(&hist).expect("parse").is_empty());
    }

    #[test]
    fn any_byte_growth_fails_but_equal_passes() {
        let hist = [
            line("tx", "aaa", &[("up_bytes", 100.0)]),
            line("tx", "bbb", &[("up_bytes", 101.0)]),
        ]
        .join("\n");
        let regs = diff_history(&hist).expect("parse");
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("up_bytes"), "{regs:?}");

        let hist = [
            line("tx", "aaa", &[("up_bytes", 100.0), ("down_bytes", 7.0)]),
            line("tx", "bbb", &[("up_bytes", 100.0), ("down_bytes", 6.0)]),
        ]
        .join("\n");
        assert!(diff_history(&hist).expect("parse").is_empty());
    }

    #[test]
    fn single_datapoint_is_skipped_and_only_last_pair_counts() {
        let hist = line("solo", "aaa", &[("x_ms", 5.0)]);
        assert!(diff_history(&hist).expect("parse").is_empty());
        // An old regression that has since recovered must not fail.
        let hist = [
            line("psr", "aaa", &[("serial_ms", 100.0)]),
            line("psr", "bbb", &[("serial_ms", 200.0)]),
            line("psr", "ccc", &[("serial_ms", 100.0)]),
        ]
        .join("\n");
        assert!(diff_history(&hist).expect("parse").is_empty());
    }

    #[test]
    fn soak_quantiles_tolerate_an_octave_but_not_more() {
        // A clean bucket flip (×2) on a quantile field is quantisation,
        // not regression — the wall_ms next to it still gates at 20%.
        let hist = [
            line("loadgen_soak", "aaa", &[("p95_ms", 40.0), ("p50_ms", 20.0)]),
            line("loadgen_soak", "bbb", &[("p95_ms", 80.0), ("p50_ms", 40.0)]),
        ]
        .join("\n");
        assert!(diff_history(&hist).expect("parse").is_empty());
        // Past an octave (×2.3) the quantile gate trips.
        let hist = [
            line("loadgen_soak", "aaa", &[("p99_ms", 40.0)]),
            line("loadgen_soak", "bbb", &[("p99_ms", 92.0)]),
        ]
        .join("\n");
        let regs = diff_history(&hist).expect("parse");
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("p99_ms"), "{regs:?}");
        // Non-quantile `_ms` fields on the same bench keep the tight gate.
        let hist = [
            line("loadgen_soak", "aaa", &[("wall_ms", 40.0)]),
            line("loadgen_soak", "bbb", &[("wall_ms", 80.0)]),
        ]
        .join("\n");
        assert_eq!(diff_history(&hist).expect("parse").len(), 1);
    }

    #[test]
    fn malformed_lines_are_hard_errors() {
        assert!(diff_history("{not json").is_err());
    }

    #[test]
    fn non_overlapping_metrics_are_ignored() {
        let hist = [
            line("psr", "aaa", &[("old_only_ms", 1.0)]),
            line("psr", "bbb", &[("new_only_ms", 900.0)]),
        ]
        .join("\n");
        assert!(diff_history(&hist).expect("parse").is_empty());
    }
}
