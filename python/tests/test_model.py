"""L2 model shape/grad sanity + training smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import model


def test_mlp_param_count():
    flat = model.mlp_init(jax.random.PRNGKey(0))
    assert flat.shape == (model.mlp_num_params(),)
    # Near the paper's 1,663,370-weight MNIST model.
    assert 1_500_000 < model.mlp_num_params() < 2_000_000


def test_mlp_forward_shapes():
    flat = model.mlp_init(jax.random.PRNGKey(1))
    x = jnp.zeros((model.MLP_BATCH, 784), jnp.float32)
    logits = model.mlp_forward(flat, x)
    assert logits.shape == (model.MLP_BATCH, 10)


def test_mlp_grad_decreases_loss():
    key = jax.random.PRNGKey(2)
    flat = model.mlp_init(key)
    x = jax.random.normal(key, (model.MLP_BATCH, 784), jnp.float32)
    y = jax.nn.one_hot(jnp.arange(model.MLP_BATCH) % 10, 10)
    loss0, g = model.mlp_grad(flat, x, y)
    assert g.shape == flat.shape
    assert np.isfinite(float(loss0))
    loss1, _ = model.mlp_grad(flat - 0.05 * g, x, y)
    assert float(loss1) < float(loss0)


def test_embbag_param_count():
    flat = model.embbag_init(jax.random.PRNGKey(3))
    assert flat.shape == (model.embbag_num_params(),)
    # Embedding dominates, mirroring the DIN census (98% embedding).
    frac = model.embbag_embedding_params() / model.embbag_num_params()
    assert frac > 0.97


def test_embbag_grad_sparsity_pattern():
    # Words absent from the batch must receive zero embedding gradient —
    # the property that makes submodel (top-k row) updates exact.
    key = jax.random.PRNGKey(4)
    flat = model.embbag_init(key)
    bow = jnp.zeros((model.EMB_BATCH, model.EMB_VOCAB), jnp.float32)
    bow = bow.at[:, :32].set(1.0)  # only the first 32 words occur
    y = jax.nn.one_hot(jnp.arange(model.EMB_BATCH) % model.EMB_CLASSES, model.EMB_CLASSES)
    _, g = model.embbag_grad(flat, bow, y)
    emb_grad = np.asarray(g[: model.embbag_embedding_params()]).reshape(
        model.EMB_VOCAB, model.EMB_DIM
    )
    assert np.abs(emb_grad[:32]).sum() > 0
    np.testing.assert_array_equal(emb_grad[32:], 0.0)


def test_embbag_training_learns():
    # A separable synthetic task must be learnable in a few steps.
    key = jax.random.PRNGKey(5)
    flat = model.embbag_init(key)
    rng = np.random.default_rng(0)
    # Class c ⇔ word block [c*50, (c+1)*50).
    labels = rng.integers(0, model.EMB_CLASSES, model.EMB_BATCH)
    bow = np.zeros((model.EMB_BATCH, model.EMB_VOCAB), np.float32)
    for i, c in enumerate(labels):
        words = rng.integers(c * 50, (c + 1) * 50, 8)
        for w in words:
            bow[i, w] += 1.0
    y = jax.nn.one_hot(jnp.asarray(labels), model.EMB_CLASSES)
    bow = jnp.asarray(bow)
    losses = []
    for _ in range(30):
        loss, g = model.embbag_grad(flat, bow, y)
        flat = flat - 0.5 * g
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]
