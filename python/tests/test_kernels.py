"""L1 kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile.kernels import binned_inner_product, matmul
from compile.kernels.matmul import _matmul_impl
from compile.kernels.ref import binned_inner_product_ref, matmul_ref

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is optional
    HAVE_HYPOTHESIS = False


def rand(shape, seed, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


class TestMatmul:
    @pytest.mark.parametrize(
        "m,k,n",
        [
            (4, 4, 4),
            (8, 16, 8),
            (50, 784, 1024),  # the MLP first layer
            (64, 18, 64),  # the embbag hidden layer
            (1, 7, 3),  # ragged, sub-block
            (300, 260, 270),  # straddles block edges
        ],
    )
    def test_matches_ref(self, m, k, n):
        x, y = rand((m, k), 1), rand((k, n), 2)
        np.testing.assert_allclose(
            np.asarray(matmul(x, y)),
            np.asarray(matmul_ref(x, y)),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_non_square_blocks(self):
        x, y = rand((16, 512), 3), rand((512, 16), 4)
        got = _matmul_impl(x, y, block_m=8, block_n=8, block_k=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(x, y)), rtol=1e-4, atol=1e-4)

    def test_zero_inputs(self):
        x = np.zeros((8, 8), np.float32)
        y = rand((8, 8), 5)
        np.testing.assert_array_equal(np.asarray(matmul(x, y)), np.zeros((8, 8)))

    if HAVE_HYPOTHESIS:

        @settings(max_examples=25, deadline=None)
        @given(
            m=st.integers(1, 40),
            k=st.integers(1, 40),
            n=st.integers(1, 40),
            seed=st.integers(0, 2**16),
        )
        def test_hypothesis_shapes(self, m, k, n, seed):
            x, y = rand((m, k), seed), rand((k, n), seed + 1)
            np.testing.assert_allclose(
                np.asarray(matmul(x, y)),
                np.asarray(matmul_ref(x, y)),
                rtol=1e-4,
                atol=1e-4,
            )


class TestBinnedInnerProduct:
    @pytest.mark.parametrize("b,theta", [(4, 8), (256, 32), (2048, 32), (7, 9)])
    def test_matches_ref(self, b, theta):
        rng = np.random.default_rng(b * 1000 + theta)
        w = rng.integers(0, 2**63, (b, theta), dtype=np.uint64)
        s = rng.integers(0, 2**63, (b, theta), dtype=np.uint64)
        got = np.asarray(binned_inner_product(jnp.asarray(w), jnp.asarray(s)))
        want = np.asarray(binned_inner_product_ref(jnp.asarray(w), jnp.asarray(s)))
        np.testing.assert_array_equal(got, want)

    def test_wrapping_semantics(self):
        # u64 products must wrap mod 2^64 exactly like the rust Group impl.
        w = jnp.array([[np.uint64(2**63)]], dtype=jnp.uint64)
        s = jnp.array([[np.uint64(3)]], dtype=jnp.uint64)
        got = np.asarray(binned_inner_product(w, s))[0]
        assert got == np.uint64((2**63 * 3) % 2**64) == np.uint64(2**63)

    def test_point_function_shape(self):
        # The PIR use: share row is a unit vector -> answer is the weight.
        w = jnp.arange(64, dtype=jnp.uint64).reshape(4, 16) + jnp.uint64(100)
        s = jnp.zeros((4, 16), jnp.uint64).at[2, 5].set(1)
        got = np.asarray(binned_inner_product(w, s))
        assert got[2] == 100 + 2 * 16 + 5
        assert got[0] == got[1] == got[3] == 0

    if HAVE_HYPOTHESIS:

        @settings(max_examples=25, deadline=None)
        @given(
            b=st.integers(1, 64),
            theta=st.integers(1, 48),
            seed=st.integers(0, 2**16),
        )
        def test_hypothesis_shapes(self, b, theta, seed):
            rng = np.random.default_rng(seed)
            w = rng.integers(0, 2**64, (b, theta), dtype=np.uint64)
            s = rng.integers(0, 2**64, (b, theta), dtype=np.uint64)
            got = np.asarray(binned_inner_product(jnp.asarray(w), jnp.asarray(s)))
            want = np.asarray(
                binned_inner_product_ref(jnp.asarray(w), jnp.asarray(s))
            )
            np.testing.assert_array_equal(got, want)
