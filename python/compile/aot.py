"""AOT lowering: JAX (L2 + L1) → HLO *text* artifacts for the rust PJRT
runtime.

HLO text, NOT ``lowered.compile()`` / serialized protos: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; python never touches the round path.

Usage: ``python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # u64 ring arithmetic in HLO

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_specs():
    """Every artifact: name → (function, example-arg specs, metadata)."""
    f32, u64 = jnp.float32, jnp.uint64
    m_mlp = model.mlp_num_params()
    m_emb = model.embbag_num_params()
    return {
        "mlp_grad": dict(
            fn=lambda p, x, y: model.mlp_grad(p, x, y),
            specs=[
                _spec((m_mlp,), f32),
                _spec((model.MLP_BATCH, 784), f32),
                _spec((model.MLP_BATCH, 10), f32),
            ],
            meta=dict(
                kind="train_step",
                params=m_mlp,
                batch=model.MLP_BATCH,
                inputs=["flat_params", "x", "y_onehot"],
                outputs=["loss", "grad"],
            ),
        ),
        "embbag_grad": dict(
            fn=lambda p, x, y: model.embbag_grad(p, x, y),
            specs=[
                _spec((m_emb,), f32),
                _spec((model.EMB_BATCH, model.EMB_VOCAB), f32),
                _spec((model.EMB_BATCH, model.EMB_CLASSES), f32),
            ],
            meta=dict(
                kind="train_step",
                params=m_emb,
                batch=model.EMB_BATCH,
                vocab=model.EMB_VOCAB,
                emb_dim=model.EMB_DIM,
                embedding_params=model.embbag_embedding_params(),
                inputs=["flat_params", "bow", "y_onehot"],
                outputs=["loss", "grad"],
            ),
        ),
        "mlp_infer": dict(
            fn=lambda p, x: (model.mlp_forward(p, x),),
            specs=[_spec((m_mlp,), f32), _spec((model.MLP_BATCH, 784), f32)],
            meta=dict(
                kind="infer",
                params=m_mlp,
                batch=model.MLP_BATCH,
                classes=10,
                inputs=["flat_params", "x"],
                outputs=["logits"],
            ),
        ),
        "embbag_infer": dict(
            fn=lambda p, x: (model.embbag_forward(p, x),),
            specs=[
                _spec((m_emb,), f32),
                _spec((model.EMB_BATCH, model.EMB_VOCAB), f32),
            ],
            meta=dict(
                kind="infer",
                params=m_emb,
                batch=model.EMB_BATCH,
                classes=model.EMB_CLASSES,
                inputs=["flat_params", "bow"],
                outputs=["logits"],
            ),
        ),
        "binned_ip": dict(
            fn=lambda w, s: (model.psr_binned_ip(w, s),),
            specs=[
                _spec((model.IP_BINS, model.IP_THETA), u64),
                _spec((model.IP_BINS, model.IP_THETA), u64),
            ],
            meta=dict(
                kind="server_ip",
                bins=model.IP_BINS,
                theta=model.IP_THETA,
                inputs=["weights_slab", "share_slab"],
                outputs=["bin_answers"],
            ),
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, spec in artifact_specs().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(spec["fn"]).lower(*spec["specs"])
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = dict(
            file=f"{name}.hlo.txt",
            arg_shapes=[list(s.shape) for s in spec["specs"]],
            arg_dtypes=[str(s.dtype) for s in spec["specs"]],
            **spec["meta"],
        )
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out, "manifest.json")
    existing = {}
    if args.only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            existing = json.load(f)
    existing.update(manifest)
    with open(manifest_path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
