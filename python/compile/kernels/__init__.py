"""L1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target
and real-TPU performance is estimated structurally (DESIGN.md
§Hardware-Adaptation).
"""

from .binned_ip import binned_inner_product
from .matmul import matmul

__all__ = ["binned_inner_product", "matmul"]
