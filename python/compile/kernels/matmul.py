"""Tiled matmul Pallas kernel — the MXU hot-spot of the L2 models.

The FSL models (MLP for the Table-7 image task, embedding-bag text
classifier for Tables 8/9) spend their FLOPs in dense matmuls. On TPU
this kernel tiles ``(M, K) @ (K, N)`` into VMEM-resident blocks streamed
by ``BlockSpec`` over a grid — the Pallas analogue of the paper's
threadblock scheme (DESIGN.md §Hardware-Adaptation). ``interpret=True``
keeps it executable on the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes sized for ~16 MiB VMEM: three f32 tiles of 256x256 ≈ 768 KiB,
# leaving headroom for double buffering.
BLOCK_M = 256
BLOCK_N = 256
BLOCK_K = 256


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step; k is innermost, so the same output block
    is revisited and used as the accumulator."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


def _matmul_impl(x, y, *, block_m=BLOCK_M, block_n=BLOCK_N, block_k=BLOCK_K):
    """``x @ y`` via the tiled Pallas kernel (f32), padding ragged edges.

    Pads each dimension up to its block multiple (zeros do not change the
    product), runs the grid, then slices the result back.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 8))
    bk = min(block_k, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


# jax.grad cannot differentiate through pallas_call directly; give the
# kernel the standard matmul VJP, with both cotangent products routed back
# through the Pallas kernel so fwd AND bwd hit the MXU path.
@jax.custom_vjp
def matmul(x, y):
    """Differentiable tiled-Pallas matmul ``x @ y`` (f32)."""
    return _matmul_impl(x, y)


def _matmul_fwd(x, y):
    return _matmul_impl(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return _matmul_impl(g, y.T), _matmul_impl(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
