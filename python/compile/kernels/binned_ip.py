"""Binned masked inner product — the PSR servers' per-bin hot loop.

After the DPF full-domain evaluation (L3, AES-bound), each PSR answer is
``out[j] = Σ_d w[j, d] · share[j, d]`` over the ring Z_2^64 — B
independent Θ-length dot products (Fig. 4, server side). That reduction
is dense VPU work, so it lives here as a Pallas kernel: bins are tiled
along the grid axis, each block holding a ``(BLOCK_B, Θ)`` slab of the
(bin-major) weight table and share matrix in VMEM.

Integer (wrapping) arithmetic: XLA u64 ops wrap mod 2^64, matching the
L3 `Group` impl for u64 exactly — the kernel is bit-identical to the
rust inner product, which is what the cross-language test asserts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 256


def _binned_ip_kernel(w_ref, s_ref, o_ref):
    o_ref[...] = (w_ref[...] * s_ref[...]).sum(axis=-1)


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


@functools.partial(jax.jit, static_argnames=("block_b",))
def binned_inner_product(w, shares, *, block_b=BLOCK_B):
    """Per-bin wrapping dot product: ``out[j] = Σ_d w[j,d]·shares[j,d]``.

    ``w`` and ``shares`` are ``uint64[B, Θ]`` (bins padded with zeros up
    to Θ — zero weights annihilate the padding shares). Returns
    ``uint64[B]``.
    """
    assert w.shape == shares.shape, (w.shape, shares.shape)
    b, theta = w.shape
    bb = min(block_b, _ceil_to(b, 8))
    bp = _ceil_to(b, bb)
    wp = jnp.pad(w.astype(jnp.uint64), ((0, bp - b), (0, 0)))
    sp = jnp.pad(shares.astype(jnp.uint64), ((0, bp - b), (0, 0)))
    out = pl.pallas_call(
        _binned_ip_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, theta), lambda i: (i, 0)),
            pl.BlockSpec((bb, theta), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.uint64),
        interpret=True,
    )(wp, sp)
    return out[:b]
