"""Pure-jnp oracles for the L1 kernels — the correctness ground truth.

Every Pallas kernel has a reference implementation here; the pytest
suite (including hypothesis shape/dtype sweeps) asserts allclose /
bit-equality between kernel and oracle.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """f32 matmul oracle."""
    return jnp.dot(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def binned_inner_product_ref(w, shares):
    """Wrapping u64 per-bin dot product oracle."""
    return (w.astype(jnp.uint64) * shares.astype(jnp.uint64)).sum(
        axis=-1, dtype=jnp.uint64
    )
