"""L2: the FSL learning workloads as JAX functions over *flat* parameter
vectors (the protocol's global weight vector ``w ∈ G^m`` is flat — the
L3 coordinator moves f32 weights through fixed-point Z_2^64 encoding).

Two models, matching the paper's evaluation tasks:

* ``mlp_*`` — the Table-7 image classifier (28×28 → 10 classes), sized
  near the paper's 1.66M-weight MNIST CNN (1,863,690 weights).
* ``embbag_*`` — the Table-8/9 text classifier: an embedding-bag +
  MLP stand-in for TextCNN, with the DIN/TREC-flavoured vocabulary
  (8,256 words) and embedding dim 18 (= the mega-element τ).

All matmuls route through the L1 Pallas kernel; ``jax.grad`` provides
the backward pass, so the AOT artifact is a single fused fwd+bwd HLO.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul

# ---------------------------------------------------------------- MLP ----

MLP_LAYERS = [(784, 1024), (1024, 1024), (1024, 10)]
MLP_BATCH = 50


def mlp_num_params() -> int:
    """Total flat parameter count (1,863,690)."""
    return sum(i * o + o for i, o in MLP_LAYERS)


def _mlp_slices():
    off = 0
    for i, o in MLP_LAYERS:
        yield off, i, o
        off += i * o + o


def mlp_init(key) -> jnp.ndarray:
    """He-initialised flat parameter vector."""
    chunks = []
    for i, o in MLP_LAYERS:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (i, o), jnp.float32) * jnp.sqrt(2.0 / i)
        chunks.append(w.reshape(-1))
        chunks.append(jnp.zeros((o,), jnp.float32))
    return jnp.concatenate(chunks)


def mlp_forward(flat, x):
    """Logits for a batch ``x : f32[B, 784]``."""
    h = x.astype(jnp.float32)
    for idx, (off, i, o) in enumerate(_mlp_slices()):
        w = jax.lax.dynamic_slice(flat, (off,), (i * o,)).reshape(i, o)
        b = jax.lax.dynamic_slice(flat, (off + i * o,), (o,))
        h = matmul(h, w) + b
        if idx + 1 < len(MLP_LAYERS):
            h = jax.nn.relu(h)
    return h


def _xent(logits, y_onehot):
    logp = jax.nn.log_softmax(logits)
    return -(y_onehot * logp).sum(axis=-1).mean()


def mlp_loss(flat, x, y_onehot):
    """Mean cross-entropy."""
    return _xent(mlp_forward(flat, x), y_onehot)


def mlp_grad(flat, x, y_onehot):
    """The AOT training-step artifact: (loss, flat gradient)."""
    loss, g = jax.value_and_grad(mlp_loss)(flat, x, y_onehot)
    return loss, g


# ---------------------------------------------------- embedding-bag ----

EMB_VOCAB = 8256  # TREC full-train vocabulary (Table 9)
EMB_DIM = 18  # = the DIN embedding dim / mega-element τ (§6, §7.5)
EMB_HIDDEN = 64
EMB_CLASSES = 6  # TREC has 6 coarse question classes
EMB_BATCH = 64


def embbag_num_params() -> int:
    """Total flat parameter count (150,214)."""
    return (
        EMB_VOCAB * EMB_DIM
        + EMB_DIM * EMB_HIDDEN
        + EMB_HIDDEN
        + EMB_HIDDEN * EMB_CLASSES
        + EMB_CLASSES
    )


def embbag_embedding_params() -> int:
    """Parameters in the embedding table (the mega-element domain)."""
    return EMB_VOCAB * EMB_DIM


def embbag_init(key) -> jnp.ndarray:
    chunks = []
    shapes = [
        (EMB_VOCAB, EMB_DIM),
        (EMB_DIM, EMB_HIDDEN),
        (EMB_HIDDEN,),
        (EMB_HIDDEN, EMB_CLASSES),
        (EMB_CLASSES,),
    ]
    for s in shapes:
        key, sub = jax.random.split(key)
        if len(s) == 2:
            chunks.append(
                (jax.random.normal(sub, s, jnp.float32) * jnp.sqrt(2.0 / s[0])).reshape(-1)
            )
        else:
            chunks.append(jnp.zeros(s, jnp.float32))
    return jnp.concatenate(chunks)


def embbag_forward(flat, bow):
    """Logits for a bag-of-words batch ``bow : f32[B, V]`` (counts)."""
    off = 0
    emb = jax.lax.dynamic_slice(flat, (off,), (EMB_VOCAB * EMB_DIM,)).reshape(
        EMB_VOCAB, EMB_DIM
    )
    off += EMB_VOCAB * EMB_DIM
    w1 = jax.lax.dynamic_slice(flat, (off,), (EMB_DIM * EMB_HIDDEN,)).reshape(
        EMB_DIM, EMB_HIDDEN
    )
    off += EMB_DIM * EMB_HIDDEN
    b1 = jax.lax.dynamic_slice(flat, (off,), (EMB_HIDDEN,))
    off += EMB_HIDDEN
    w2 = jax.lax.dynamic_slice(flat, (off,), (EMB_HIDDEN * EMB_CLASSES,)).reshape(
        EMB_HIDDEN, EMB_CLASSES
    )
    off += EMB_HIDDEN * EMB_CLASSES
    b2 = jax.lax.dynamic_slice(flat, (off,), (EMB_CLASSES,))

    # Embedding-bag: sum of word vectors = bow @ emb (an MXU matmul —
    # exactly why embedding rows group naturally into mega-elements).
    e = matmul(bow.astype(jnp.float32), emb)
    h = jax.nn.relu(matmul(e, w1) + b1)
    return matmul(h, w2) + b2


def embbag_loss(flat, bow, y_onehot):
    """Mean cross-entropy."""
    return _xent(embbag_forward(flat, bow), y_onehot)


def embbag_grad(flat, bow, y_onehot):
    """The AOT training-step artifact: (loss, flat gradient)."""
    loss, g = jax.value_and_grad(embbag_loss)(flat, bow, y_onehot)
    return loss, g


# ------------------------------------------------ server-side graphs ----

# Padded bin-matrix shape for the PSR inner-product artifact: the L3
# runtime chunks/pads sessions into (BINS, THETA) slabs.
IP_BINS = 2048
IP_THETA = 32


def psr_binned_ip(w, shares):
    """Server answer slab: per-bin wrapping-u64 inner products (L1 kernel)."""
    from .kernels import binned_inner_product

    return binned_inner_product(w, shares)
